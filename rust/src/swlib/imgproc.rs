//! CPU image-processing functions (ports of `ref.py`, replicate borders).
//!
//! Optimized for the steady-state frame path: every kernel has an
//! `_into`-style out-parameter variant so stage outputs can draw from the
//! pipeline's [`BufferPool`], each 3x3 stencil runs an interior fast path
//! over raw slices (no clamped loads, autovectorizable) plus a clamped
//! border pass, the Gaussian is a separable two-pass, Sobel dx+dy fuse
//! into one image walk, and [`harris_pipeline`] covers the whole
//! gray→response chain in one call.  The pre-optimization kernels live in
//! [`reference`] as the parity oracle: the property suite in
//! `tests/kernel_parity.rs` pins every fast path to them bit-for-bit
//! (separable Gaussian: to ~1 ULP, the reassociation cost of the second
//! pass).
//!
//! Two orthogonal interior accelerators, both parity-preserving:
//!
//! * **row bands** ([`super::banding`]) — every interior pass splits its
//!   row range into [`band_hint`] contiguous bands on scoped threads
//!   (sources shared immutably, so halo rows are plain reads; each
//!   output row keeps its sequential arithmetic → bitwise identical);
//! * **SIMD lanes** ([`super::simd::F32x8`]) — the unrolled per-pixel
//!   expressions re-stated lanewise in the same evaluation order, with a
//!   scalar tail; selected at runtime by [`simd_enabled`].

use super::banding::{band_exec, band_exec2, band_exec3, band_hint, simd_enabled};
use super::simd::{F32x8, LANES};
use crate::image::Mat;
use crate::pipeline::BufferPool;
use crate::{CourierError, Result};

/// BT.601 luma weights (match `kernels/common.py`).
pub const LUMA_R: f32 = 0.299;
pub const LUMA_G: f32 = 0.587;
pub const LUMA_B: f32 = 0.114;

/// Harris k constant (matches `kernels/harris.py`).
pub const HARRIS_K: f32 = 0.04;

const SOBEL_DX: [[f32; 3]; 3] = [[-1.0, 0.0, 1.0], [-2.0, 0.0, 2.0], [-1.0, 0.0, 1.0]];
const SOBEL_DY: [[f32; 3]; 3] = [[-1.0, -2.0, -1.0], [0.0, 0.0, 0.0], [1.0, 2.0, 1.0]];
const GAUSS3: [[f32; 3]; 3] = [
    [1.0 / 16.0, 2.0 / 16.0, 1.0 / 16.0],
    [2.0 / 16.0, 4.0 / 16.0, 2.0 / 16.0],
    [1.0 / 16.0, 2.0 / 16.0, 1.0 / 16.0],
];
const LAPLACIAN: [[f32; 3]; 3] = [[0.0, 1.0, 0.0], [1.0, -4.0, 1.0], [0.0, 1.0, 0.0]];
const SCHARR_DX: [[f32; 3]; 3] = [[-3.0, 0.0, 3.0], [-10.0, 0.0, 10.0], [-3.0, 0.0, 3.0]];

fn expect_gray(m: &Mat, context: &str) -> Result<()> {
    if m.shape().len() != 2 {
        return Err(CourierError::ShapeMismatch {
            context: context.into(),
            expected: "(H, W) single-channel".into(),
            got: format!("{:?}", m.shape()),
        });
    }
    Ok(())
}

fn expect_out_shape(out: &Mat, shape: &[usize], context: &str) -> Result<()> {
    if out.shape() != shape {
        return Err(CourierError::ShapeMismatch {
            context: format!("{context} (out)"),
            expected: format!("{shape:?}"),
            got: format!("{:?}", out.shape()),
        });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// generic 3x3 stencil: interior fast path + clamped border pass
// ---------------------------------------------------------------------------

/// 3x3 convolution with replicate border into `out` (same shape).
///
/// Interior pixels read raw row slices with the stencil fully unrolled —
/// no clamped loads, no per-tap zero check, bounds checks hoisted to the
/// row slices — and only the one-pixel border falls back to the clamped
/// reference loop.  Zero taps contribute an exact `+0.0`, so results
/// compare equal (`==`) to the skip-zero reference everywhere.
fn conv3x3_into(img: &Mat, taps: &[[f32; 3]; 3], out: &mut Mat) {
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return;
    }
    let src = img.as_slice();
    let t = taps;
    if h > 2 && w > 2 {
        let simd = simd_enabled();
        let dst = out.as_mut_slice();
        band_exec(dst, w, 1, h - 1, band_hint(), |y0, y1, chunk| {
            for y in y0..y1 {
                let r0 = &src[(y - 1) * w..y * w];
                let r1 = &src[y * w..(y + 1) * w];
                let r2 = &src[(y + 1) * w..(y + 2) * w];
                let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                conv3x3_row(t, r0, r1, r2, drow, simd);
            }
        });
    }
    conv3x3_border(img, taps, out);
}

/// One interior row of [`conv3x3_into`]: columns `1..w-1` of `drow`
/// from full source rows `r0`/`r1`/`r2`.  The vector body is the scalar
/// expression restated lanewise in the same order (bitwise identical);
/// the tail (and the whole row with SIMD off) runs the scalar loop.
#[inline]
fn conv3x3_row(
    t: &[[f32; 3]; 3],
    r0: &[f32],
    r1: &[f32],
    r2: &[f32],
    drow: &mut [f32],
    simd: bool,
) {
    let w = drow.len();
    let mut x = 1usize;
    if simd {
        let (t00, t01, t02) =
            (F32x8::splat(t[0][0]), F32x8::splat(t[0][1]), F32x8::splat(t[0][2]));
        let (t10, t11, t12) =
            (F32x8::splat(t[1][0]), F32x8::splat(t[1][1]), F32x8::splat(t[1][2]));
        let (t20, t21, t22) =
            (F32x8::splat(t[2][0]), F32x8::splat(t[2][1]), F32x8::splat(t[2][2]));
        while x + LANES <= w - 1 {
            let v = t00 * F32x8::load(&r0[x - 1..])
                + t01 * F32x8::load(&r0[x..])
                + t02 * F32x8::load(&r0[x + 1..])
                + t10 * F32x8::load(&r1[x - 1..])
                + t11 * F32x8::load(&r1[x..])
                + t12 * F32x8::load(&r1[x + 1..])
                + t20 * F32x8::load(&r2[x - 1..])
                + t21 * F32x8::load(&r2[x..])
                + t22 * F32x8::load(&r2[x + 1..]);
            v.store(&mut drow[x..]);
            x += LANES;
        }
    }
    for x in x..w - 1 {
        drow[x] = t[0][0] * r0[x - 1]
            + t[0][1] * r0[x]
            + t[0][2] * r0[x + 1]
            + t[1][0] * r1[x - 1]
            + t[1][1] * r1[x]
            + t[1][2] * r1[x + 1]
            + t[2][0] * r2[x - 1]
            + t[2][1] * r2[x]
            + t[2][2] * r2[x + 1];
    }
}

/// One clamped-border stencil evaluation (the reference inner loop).
fn conv3x3_cell(img: &Mat, taps: &[[f32; 3]; 3], y: usize, x: usize) -> f32 {
    let mut acc = 0.0f32;
    for (dy, row) in taps.iter().enumerate() {
        for (dx, &t) in row.iter().enumerate() {
            if t == 0.0 {
                continue;
            }
            acc += t * img.at2_clamped(y as isize + dy as isize - 1, x as isize + dx as isize - 1);
        }
    }
    acc
}

/// Border pass of [`conv3x3_into`]: top/bottom rows and left/right
/// columns via clamped loads.
fn conv3x3_border(img: &Mat, taps: &[[f32; 3]; 3], out: &mut Mat) {
    let (h, w) = (img.height(), img.width());
    let dst = out.as_mut_slice();
    for x in 0..w {
        dst[x] = conv3x3_cell(img, taps, 0, x);
        dst[(h - 1) * w + x] = conv3x3_cell(img, taps, h - 1, x);
    }
    for y in 0..h {
        dst[y * w] = conv3x3_cell(img, taps, y, 0);
        dst[y * w + w - 1] = conv3x3_cell(img, taps, y, w - 1);
    }
}

// ---------------------------------------------------------------------------
// color conversion
// ---------------------------------------------------------------------------

/// RGB (H, W, 3) -> gray (H, W), BT.601 — `cv::cvtColor(RGB2GRAY)`.
pub fn cvt_color(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(&[img.height(), img.width()]);
    cvt_color_into(img, &mut out)?;
    Ok(out)
}

/// [`cvt_color`] into a caller-provided (H, W) buffer.
pub fn cvt_color_into(img: &Mat, out: &mut Mat) -> Result<()> {
    if img.shape().len() != 3 || img.channels() != 3 {
        return Err(CourierError::ShapeMismatch {
            context: "cvt_color".into(),
            expected: "(H, W, 3)".into(),
            got: format!("{:?}", img.shape()),
        });
    }
    let (h, w) = (img.height(), img.width());
    expect_out_shape(out, &[h, w], "cvt_color")?;
    let src = img.as_slice();
    let dst = out.as_mut_slice();
    band_exec(dst, w, 0, h, band_hint(), |y0, y1, chunk| {
        let off = y0 * w;
        for i in off..y1 * w {
            let base = i * 3;
            chunk[i - off] = LUMA_R * src[base] + LUMA_G * src[base + 1] + LUMA_B * src[base + 2];
        }
    });
    Ok(())
}

// ---------------------------------------------------------------------------
// derivative / smoothing stencils
// ---------------------------------------------------------------------------

/// 3x3 Sobel derivative — `cv::Sobel` (ksize 3). Exactly one of dx/dy = 1.
pub fn sobel(img: &Mat, dx: u8, dy: u8) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    sobel_into(img, dx, dy, &mut out)?;
    Ok(out)
}

/// [`sobel`] into a caller-provided same-shape buffer.
pub fn sobel_into(img: &Mat, dx: u8, dy: u8, out: &mut Mat) -> Result<()> {
    expect_gray(img, "sobel")?;
    expect_out_shape(out, img.shape(), "sobel")?;
    match (dx, dy) {
        (1, 0) => conv3x3_into(img, &SOBEL_DX, out),
        (0, 1) => conv3x3_into(img, &SOBEL_DY, out),
        _ => return Err(CourierError::Other("sobel: exactly one of dx/dy must be 1".into())),
    }
    Ok(())
}

/// Fused Sobel dx+dy: both gradients in **one image walk** (the gradient
/// pair every corner detector needs — two separate `sobel` calls read the
/// image twice for no reason).  Each gradient accumulates in its own tap
/// order, so both match their split-kernel counterparts exactly.
pub fn sobel_xy_into(img: &Mat, dx: &mut Mat, dy: &mut Mat) -> Result<()> {
    expect_gray(img, "sobel_xy")?;
    expect_out_shape(dx, img.shape(), "sobel_xy dx")?;
    expect_out_shape(dy, img.shape(), "sobel_xy dy")?;
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return Ok(());
    }
    let src = img.as_slice();
    if h > 2 && w > 2 {
        let simd = simd_enabled();
        let dxs = dx.as_mut_slice();
        let dys = dy.as_mut_slice();
        band_exec2(dxs, dys, w, 1, h - 1, band_hint(), |y0, y1, cx, cy| {
            for y in y0..y1 {
                let r0 = &src[(y - 1) * w..y * w];
                let r1 = &src[y * w..(y + 1) * w];
                let r2 = &src[(y + 1) * w..(y + 2) * w];
                let o = (y - y0) * w;
                sobel_xy_row(r0, r1, r2, &mut cx[o..o + w], &mut cy[o..o + w], simd);
            }
        });
    }
    conv3x3_border(img, &SOBEL_DX, dx);
    conv3x3_border(img, &SOBEL_DY, dy);
    Ok(())
}

/// One interior row of the fused Sobel pair (columns `1..w-1`).
#[inline]
fn sobel_xy_row(r0: &[f32], r1: &[f32], r2: &[f32], xrow: &mut [f32], yrow: &mut [f32], simd: bool) {
    let w = xrow.len();
    let mut x = 1usize;
    if simd {
        let two = F32x8::splat(2.0);
        while x + LANES <= w - 1 {
            let a = F32x8::load(&r0[x - 1..]);
            let b = F32x8::load(&r0[x..]);
            let c = F32x8::load(&r0[x + 1..]);
            let d = F32x8::load(&r1[x - 1..]);
            let f = F32x8::load(&r1[x + 1..]);
            let g = F32x8::load(&r2[x - 1..]);
            let hh = F32x8::load(&r2[x..]);
            let i = F32x8::load(&r2[x + 1..]);
            (-a + c - two * d + two * f - g + i).store(&mut xrow[x..]);
            (-a - two * b - c + g + two * hh + i).store(&mut yrow[x..]);
            x += LANES;
        }
    }
    for x in x..w - 1 {
        let (a, b, c) = (r0[x - 1], r0[x], r0[x + 1]);
        let (d, f) = (r1[x - 1], r1[x + 1]);
        let (g, hh, i) = (r2[x - 1], r2[x], r2[x + 1]);
        xrow[x] = -a + c - 2.0 * d + 2.0 * f - g + i;
        yrow[x] = -a - 2.0 * b - c + g + 2.0 * hh + i;
    }
}

/// 3x3 Gaussian — `cv::GaussianBlur(3x3)`, separable two-pass.
pub fn gaussian_blur(img: &Mat) -> Result<Mat> {
    expect_gray(img, "gaussian_blur")?;
    let mut tmp = Mat::zeros(img.shape());
    let mut out = Mat::zeros(img.shape());
    gaussian_blur_into(img, &mut tmp, &mut out)?;
    Ok(out)
}

/// Separable two-pass Gaussian into caller-provided buffers: horizontal
/// then vertical `[1, 2, 1]/4` with replicate borders.  The outer product
/// of the passes is exactly the 2-D `GAUSS3` stencil (all weights are
/// powers of two), so results agree with [`reference::gaussian_blur`] to
/// ~1 ULP — one image walk cheaper and a much smaller working set.
pub fn gaussian_blur_into(img: &Mat, tmp: &mut Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "gaussian_blur")?;
    expect_out_shape(tmp, img.shape(), "gaussian_blur tmp")?;
    expect_out_shape(out, img.shape(), "gaussian_blur")?;
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return Ok(());
    }
    let src = img.as_slice();
    let bands = band_hint();
    let simd = simd_enabled();
    {
        let t = tmp.as_mut_slice();
        band_exec(t, w, 0, h, bands, |y0, y1, chunk| {
            for y in y0..y1 {
                let row = &src[y * w..(y + 1) * w];
                let trow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                gaussian_h_row(row, trow, simd);
            }
        });
    }
    // the band_exec scope join above is the barrier: every horizontal
    // row is complete before any vertical band reads across a boundary
    {
        let t = tmp.as_slice();
        let dst = out.as_mut_slice();
        band_exec(dst, w, 0, h, bands, |y0, y1, chunk| {
            for y in y0..y1 {
                let ym = y.saturating_sub(1);
                let yp = (y + 1).min(h - 1);
                let r0 = &t[ym * w..ym * w + w];
                let r1 = &t[y * w..y * w + w];
                let r2 = &t[yp * w..yp * w + w];
                let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                gaussian_v_row(r0, r1, r2, drow, simd);
            }
        });
    }
    Ok(())
}

/// One horizontal `[1, 2, 1]/4` pass row (replicate ends).
#[inline]
fn gaussian_h_row(row: &[f32], trow: &mut [f32], simd: bool) {
    let w = trow.len();
    trow[0] = 0.25 * row[0] + 0.5 * row[0] + 0.25 * row[1.min(w - 1)];
    let mut x = 1usize;
    if simd {
        let (q, hlf) = (F32x8::splat(0.25), F32x8::splat(0.5));
        while x + LANES <= w - 1 {
            let v = q * F32x8::load(&row[x - 1..])
                + hlf * F32x8::load(&row[x..])
                + q * F32x8::load(&row[x + 1..]);
            v.store(&mut trow[x..]);
            x += LANES;
        }
    }
    for x in x..w.saturating_sub(1) {
        trow[x] = 0.25 * row[x - 1] + 0.5 * row[x] + 0.25 * row[x + 1];
    }
    if w > 1 {
        trow[w - 1] = 0.25 * row[w - 2] + 0.5 * row[w - 1] + 0.25 * row[w - 1];
    }
}

/// One vertical `[1, 2, 1]/4` pass row (`r0`/`r1`/`r2` pre-clamped).
#[inline]
fn gaussian_v_row(r0: &[f32], r1: &[f32], r2: &[f32], drow: &mut [f32], simd: bool) {
    let w = drow.len();
    let mut x = 0usize;
    if simd {
        let (q, hlf) = (F32x8::splat(0.25), F32x8::splat(0.5));
        while x + LANES <= w {
            let v = q * F32x8::load(&r0[x..])
                + hlf * F32x8::load(&r1[x..])
                + q * F32x8::load(&r2[x..]);
            v.store(&mut drow[x..]);
            x += LANES;
        }
    }
    for x in x..w {
        drow[x] = 0.25 * r0[x] + 0.5 * r1[x] + 0.25 * r2[x];
    }
}

/// [`gaussian_blur_into`] with pooled, *banded* scratch: instead of one
/// full-frame tmp, each row band draws an overlapped tile (its rows plus
/// one halo row each side) via [`BufferPool::acquire_band_scratch`],
/// h-passes into it, and v-passes straight to `out`.  Halo rows are
/// recomputed by both neighbouring bands — the classic overlapped-tiling
/// trade: a couple of redundant rows of work buys zero cross-band
/// synchronization and an `O(rows/bands)` working set per thread.
/// Bitwise identical to the two-pass path, because every scratch row is
/// the h-pass of the same source row.
pub fn gaussian_blur_pooled(img: &Mat, pool: &BufferPool) -> Result<Mat> {
    expect_gray(img, "gaussian_blur")?;
    let (h, w) = (img.height(), img.width());
    let mut out = pool.acquire(&[h, w]);
    if h == 0 || w == 0 {
        return Ok(out);
    }
    let bands = band_hint();
    if bands <= 1 {
        let mut tmp = pool.acquire(&[h, w]);
        let res = gaussian_blur_into(img, &mut tmp, &mut out);
        pool.release(tmp);
        return res.map(|()| out);
    }
    let src = img.as_slice();
    let simd = simd_enabled();
    let dst = out.as_mut_slice();
    band_exec(dst, w, 0, h, bands, |y0, y1, chunk| {
        let sy0 = y0.saturating_sub(1);
        let sy1 = (y1 + 1).min(h);
        let mut scratch = pool.acquire_band_scratch(&[h, w], &[sy1 - sy0, w]);
        {
            let t = scratch.as_mut_slice();
            for y in sy0..sy1 {
                let row = &src[y * w..(y + 1) * w];
                let trow = &mut t[(y - sy0) * w..(y - sy0 + 1) * w];
                gaussian_h_row(row, trow, simd);
            }
        }
        {
            let t = scratch.as_slice();
            for y in y0..y1 {
                let ym = y.saturating_sub(1);
                let yp = (y + 1).min(h - 1);
                let r0 = &t[(ym - sy0) * w..(ym - sy0 + 1) * w];
                let r1 = &t[(y - sy0) * w..(y - sy0 + 1) * w];
                let r2 = &t[(yp - sy0) * w..(yp - sy0 + 1) * w];
                let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                gaussian_v_row(r0, r1, r2, drow, simd);
            }
        }
        pool.release(scratch);
    });
    Ok(out)
}

/// Gaussian pyramid downsample — `cv::pyrDown`: 3x3 Gaussian smooth
/// then even-row/column decimation to `((h+1)/2, (w+1)/2)`.
pub fn pyr_down(img: &Mat) -> Result<Mat> {
    expect_gray(img, "pyr_down")?;
    let blurred = gaussian_blur(img)?;
    let mut out = Mat::zeros(&[(img.height() + 1) / 2, (img.width() + 1) / 2]);
    decimate2_into(&blurred, &mut out);
    Ok(out)
}

/// [`pyr_down`] with the blur intermediate and the half-size output drawn
/// from the pool.  The shape-halving step is what exercises the pool's
/// capacity-class downcycling: a retired full-size buffer recycles into
/// the smaller class the next level acquires from.  Bitwise identical to
/// the plain path ([`gaussian_blur_pooled`] is bitwise-stable, and
/// decimation only copies).
pub fn pyr_down_pooled(img: &Mat, pool: &BufferPool) -> Result<Mat> {
    expect_gray(img, "pyr_down")?;
    let blurred = gaussian_blur_pooled(img, pool)?;
    let mut out = pool.acquire(&[(img.height() + 1) / 2, (img.width() + 1) / 2]);
    decimate2_into(&blurred, &mut out);
    pool.release(blurred);
    Ok(out)
}

/// Keep every even row/column of `src` (`out` already has the pyramid
/// shape, so the loop bounds are the decimated extents).
fn decimate2_into(src: &Mat, out: &mut Mat) {
    let (oh, ow) = (out.height(), out.width());
    let w = src.width();
    let s = src.as_slice();
    let d = out.as_mut_slice();
    for y in 0..oh {
        for x in 0..ow {
            d[y * ow + x] = s[2 * y * w + 2 * x];
        }
    }
}

/// 3x3 box filter — `cv::boxFilter` (mean when `normalize`).
pub fn box_filter(img: &Mat, normalize: bool) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    box_filter_into(img, normalize, &mut out)?;
    Ok(out)
}

/// [`box_filter`] into a caller-provided same-shape buffer.
pub fn box_filter_into(img: &Mat, normalize: bool, out: &mut Mat) -> Result<()> {
    expect_gray(img, "box_filter")?;
    expect_out_shape(out, img.shape(), "box_filter")?;
    let t = if normalize { 1.0 / 9.0 } else { 1.0 };
    conv3x3_into(img, &[[t; 3]; 3], out);
    Ok(())
}

/// 3x3 Laplacian — `cv::Laplacian` (ksize 3, no scaling).
pub fn laplacian(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    laplacian_into(img, &mut out)?;
    Ok(out)
}

/// [`laplacian`] into a caller-provided same-shape buffer.
pub fn laplacian_into(img: &Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "laplacian")?;
    expect_out_shape(out, img.shape(), "laplacian")?;
    conv3x3_into(img, &LAPLACIAN, out);
    Ok(())
}

/// 3x3 Scharr d/dx — `cv::Scharr`.
pub fn scharr(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    scharr_into(img, &mut out)?;
    Ok(out)
}

/// [`scharr`] into a caller-provided same-shape buffer.
pub fn scharr_into(img: &Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "scharr")?;
    expect_out_shape(out, img.shape(), "scharr")?;
    conv3x3_into(img, &SCHARR_DX, out);
    Ok(())
}

// ---------------------------------------------------------------------------
// rank / morphology windows
// ---------------------------------------------------------------------------

/// Partial selection sort to the middle of a 9-window (the reference's
/// exact algorithm, shared by both the interior and border paths).
fn median9(window: &mut [f32; 9]) -> f32 {
    for i in 0..=4 {
        let mut min_i = i;
        for j in i + 1..9 {
            if window[j] < window[min_i] {
                min_i = j;
            }
        }
        window.swap(i, min_i);
    }
    window[4]
}

fn median_window_clamped(img: &Mat, y: usize, x: usize) -> f32 {
    let mut window = [0.0f32; 9];
    let mut k = 0;
    for dy in -1isize..=1 {
        for dx in -1isize..=1 {
            window[k] = img.at2_clamped(y as isize + dy, x as isize + dx);
            k += 1;
        }
    }
    median9(&mut window)
}

/// 3x3 median — `cv::medianBlur(3)` (replicate border).
pub fn median_blur(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    median_blur_into(img, &mut out)?;
    Ok(out)
}

/// [`median_blur`] into a caller-provided same-shape buffer.
pub fn median_blur_into(img: &Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "median_blur")?;
    expect_out_shape(out, img.shape(), "median_blur")?;
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return Ok(());
    }
    let src = img.as_slice();
    if h > 2 && w > 2 {
        let dst = out.as_mut_slice();
        // rank filter: no useful SIMD shape, but the rows band like any
        // other interior stencil (sources stay shared, halo reads free)
        band_exec(dst, w, 1, h - 1, band_hint(), |y0, y1, chunk| {
            for y in y0..y1 {
                let r0 = &src[(y - 1) * w..y * w];
                let r1 = &src[y * w..(y + 1) * w];
                let r2 = &src[(y + 1) * w..(y + 2) * w];
                let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                for x in 1..w - 1 {
                    let mut window = [
                        r0[x - 1], r0[x], r0[x + 1], r1[x - 1], r1[x], r1[x + 1], r2[x - 1],
                        r2[x], r2[x + 1],
                    ];
                    drow[x] = median9(&mut window);
                }
            }
        });
    }
    {
        let dst = out.as_mut_slice();
        for x in 0..w {
            dst[x] = median_window_clamped(img, 0, x);
            dst[(h - 1) * w + x] = median_window_clamped(img, h - 1, x);
        }
        for y in 0..h {
            dst[y * w] = median_window_clamped(img, y, 0);
            dst[y * w + w - 1] = median_window_clamped(img, y, w - 1);
        }
    }
    Ok(())
}

/// 3x3 erosion (window min) — `cv::erode`.
pub fn erode(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    erode_into(img, &mut out)?;
    Ok(out)
}

/// [`erode`] into a caller-provided same-shape buffer.
pub fn erode_into(img: &Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "erode")?;
    expect_out_shape(out, img.shape(), "erode")?;
    morph_into(img, MorphOp::Min, out);
    Ok(())
}

/// 3x3 dilation (window max) — `cv::dilate`.
pub fn dilate(img: &Mat) -> Result<Mat> {
    let mut out = Mat::zeros(img.shape());
    dilate_into(img, &mut out)?;
    Ok(out)
}

/// [`dilate`] into a caller-provided same-shape buffer.
pub fn dilate_into(img: &Mat, out: &mut Mat) -> Result<()> {
    expect_gray(img, "dilate")?;
    expect_out_shape(out, img.shape(), "dilate")?;
    morph_into(img, MorphOp::Max, out);
    Ok(())
}

/// Window reduction selector — scalar and lanewise forms apply the same
/// op in the same order, so both paths agree bit for bit (`f32::min`/
/// `f32::max` semantics, lanewise).
#[derive(Clone, Copy, PartialEq, Eq)]
enum MorphOp {
    Min,
    Max,
}

impl MorphOp {
    #[inline(always)]
    fn fold(self, a: f32, b: f32) -> f32 {
        match self {
            MorphOp::Min => a.min(b),
            MorphOp::Max => a.max(b),
        }
    }

    #[inline(always)]
    fn fold_v(self, a: F32x8, b: F32x8) -> F32x8 {
        match self {
            MorphOp::Min => a.min(b),
            MorphOp::Max => a.max(b),
        }
    }
}

fn morph_cell_clamped(img: &Mat, op: MorphOp, y: usize, x: usize) -> f32 {
    let mut acc = img.at2_clamped(y as isize - 1, x as isize - 1);
    for dy in 0..3isize {
        for dx in 0..3isize {
            acc = op.fold(acc, img.at2_clamped(y as isize + dy - 1, x as isize + dx - 1));
        }
    }
    acc
}

fn morph_into(img: &Mat, op: MorphOp, out: &mut Mat) {
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return;
    }
    let src = img.as_slice();
    if h > 2 && w > 2 {
        let simd = simd_enabled();
        let dst = out.as_mut_slice();
        band_exec(dst, w, 1, h - 1, band_hint(), |y0, y1, chunk| {
            for y in y0..y1 {
                let r0 = &src[(y - 1) * w..y * w];
                let r1 = &src[y * w..(y + 1) * w];
                let r2 = &src[(y + 1) * w..(y + 2) * w];
                let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
                morph_row(op, r0, r1, r2, drow, simd);
            }
        });
    }
    let dst = out.as_mut_slice();
    for x in 0..w {
        dst[x] = morph_cell_clamped(img, op, 0, x);
        dst[(h - 1) * w + x] = morph_cell_clamped(img, op, h - 1, x);
    }
    for y in 0..h {
        dst[y * w] = morph_cell_clamped(img, op, y, 0);
        dst[y * w + w - 1] = morph_cell_clamped(img, op, y, w - 1);
    }
}

/// One interior morphology row: seed with `r0[x-1]`, fold the nine
/// window cells in the reference order (the seed cell folds twice,
/// exactly like the scalar loop always has).
#[inline]
fn morph_row(op: MorphOp, r0: &[f32], r1: &[f32], r2: &[f32], drow: &mut [f32], simd: bool) {
    let w = drow.len();
    let mut x = 1usize;
    if simd {
        while x + LANES <= w - 1 {
            let mut acc = F32x8::load(&r0[x - 1..]);
            acc = op.fold_v(acc, F32x8::load(&r0[x - 1..]));
            acc = op.fold_v(acc, F32x8::load(&r0[x..]));
            acc = op.fold_v(acc, F32x8::load(&r0[x + 1..]));
            acc = op.fold_v(acc, F32x8::load(&r1[x - 1..]));
            acc = op.fold_v(acc, F32x8::load(&r1[x..]));
            acc = op.fold_v(acc, F32x8::load(&r1[x + 1..]));
            acc = op.fold_v(acc, F32x8::load(&r2[x - 1..]));
            acc = op.fold_v(acc, F32x8::load(&r2[x..]));
            acc = op.fold_v(acc, F32x8::load(&r2[x + 1..]));
            acc.store(&mut drow[x..]);
            x += LANES;
        }
    }
    for x in x..w - 1 {
        let mut acc = r0[x - 1];
        acc = op.fold(acc, r0[x - 1]);
        acc = op.fold(acc, r0[x]);
        acc = op.fold(acc, r0[x + 1]);
        acc = op.fold(acc, r1[x - 1]);
        acc = op.fold(acc, r1[x]);
        acc = op.fold(acc, r1[x + 1]);
        acc = op.fold(acc, r2[x - 1]);
        acc = op.fold(acc, r2[x]);
        acc = op.fold(acc, r2[x + 1]);
        drow[x] = acc;
    }
}

/// Fused one-walk morphology pair — `cv::erode` + `cv::dilate` over one
/// shared input: every 3x3 window is loaded once and folded into both the
/// min and the max reduction.  The morphological-gradient fork (a flow
/// branching the same smoothed image into erosion and dilation) pays one
/// image walk instead of two; each accumulator folds its cells in
/// [`morph_row`]'s reference order, so both outputs match their split
/// kernels bit for bit.
pub fn erode_dilate_into(img: &Mat, er: &mut Mat, di: &mut Mat) -> Result<()> {
    expect_gray(img, "erode_dilate")?;
    expect_out_shape(er, img.shape(), "erode_dilate er")?;
    expect_out_shape(di, img.shape(), "erode_dilate di")?;
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return Ok(());
    }
    let src = img.as_slice();
    if h > 2 && w > 2 {
        let simd = simd_enabled();
        let ers = er.as_mut_slice();
        let dis = di.as_mut_slice();
        band_exec2(ers, dis, w, 1, h - 1, band_hint(), |y0, y1, ce, cd| {
            for y in y0..y1 {
                let r0 = &src[(y - 1) * w..y * w];
                let r1 = &src[y * w..(y + 1) * w];
                let r2 = &src[(y + 1) * w..(y + 2) * w];
                let o = (y - y0) * w;
                erode_dilate_row(r0, r1, r2, &mut ce[o..o + w], &mut cd[o..o + w], simd);
            }
        });
    }
    for (op, out) in [(MorphOp::Min, &mut *er), (MorphOp::Max, &mut *di)] {
        let dst = out.as_mut_slice();
        for x in 0..w {
            dst[x] = morph_cell_clamped(img, op, 0, x);
            dst[(h - 1) * w + x] = morph_cell_clamped(img, op, h - 1, x);
        }
        for y in 0..h {
            dst[y * w] = morph_cell_clamped(img, op, y, 0);
            dst[y * w + w - 1] = morph_cell_clamped(img, op, y, w - 1);
        }
    }
    Ok(())
}

/// One interior row of the fused morphology pair: the nine window cells
/// load once and fold into both reductions in [`morph_row`]'s order
/// (seed `r0[x-1]`, which therefore folds twice into each accumulator).
#[inline]
fn erode_dilate_row(
    r0: &[f32],
    r1: &[f32],
    r2: &[f32],
    erow: &mut [f32],
    drow: &mut [f32],
    simd: bool,
) {
    let w = erow.len();
    let mut x = 1usize;
    if simd {
        while x + LANES <= w - 1 {
            let cells = [
                F32x8::load(&r0[x - 1..]),
                F32x8::load(&r0[x..]),
                F32x8::load(&r0[x + 1..]),
                F32x8::load(&r1[x - 1..]),
                F32x8::load(&r1[x..]),
                F32x8::load(&r1[x + 1..]),
                F32x8::load(&r2[x - 1..]),
                F32x8::load(&r2[x..]),
                F32x8::load(&r2[x + 1..]),
            ];
            let mut mn = cells[0];
            let mut mx = cells[0];
            for c in cells {
                mn = MorphOp::Min.fold_v(mn, c);
                mx = MorphOp::Max.fold_v(mx, c);
            }
            mn.store(&mut erow[x..]);
            mx.store(&mut drow[x..]);
            x += LANES;
        }
    }
    for x in x..w - 1 {
        let cells = [
            r0[x - 1],
            r0[x],
            r0[x + 1],
            r1[x - 1],
            r1[x],
            r1[x + 1],
            r2[x - 1],
            r2[x],
            r2[x + 1],
        ];
        let mut mn = cells[0];
        let mut mx = cells[0];
        for c in cells {
            mn = MorphOp::Min.fold(mn, c);
            mx = MorphOp::Max.fold(mx, c);
        }
        erow[x] = mn;
        drow[x] = mx;
    }
}

// ---------------------------------------------------------------------------
// Harris
// ---------------------------------------------------------------------------

/// Harris-Stephens corner response — `cv::cornerHarris(blockSize=3, ksize=3)`.
///
/// Matches the fused Pallas kernel exactly: the *image* is edge-padded by
/// 2, Sobel is a valid conv to (H+2, W+2), products, then a valid
/// unnormalized 3x3 window sum back to (H, W), `R = det(M) - k*trace(M)^2`.
/// (Padding the image once and convolving valid is NOT the same at the
/// borders as clamp-indexing each convolution — e.g. the replicated row's
/// Sobel dy is zero.)
pub fn corner_harris(img: &Mat, k: f32) -> Result<Mat> {
    expect_gray(img, "corner_harris")?;
    let (h, w) = (img.height(), img.width());
    let mut padded = Mat::zeros(&[h + 4, w + 4]);
    let mut dx = Mat::zeros(&[h + 2, w + 2]);
    let mut dy = Mat::zeros(&[h + 2, w + 2]);
    let mut dxy = Mat::zeros(&[h + 2, w + 2]);
    let mut out = Mat::zeros(&[h, w]);
    corner_harris_core(img, k, &mut padded, &mut dx, &mut dy, &mut dxy, &mut out);
    Ok(out)
}

/// [`corner_harris`] with every scratch buffer drawn from (and returned
/// to) the pool — the steady-state zero-allocation path.
pub fn corner_harris_pooled(img: &Mat, k: f32, pool: &BufferPool) -> Result<Mat> {
    expect_gray(img, "corner_harris")?;
    let (h, w) = (img.height(), img.width());
    let mut padded = pool.acquire(&[h + 4, w + 4]);
    let mut dx = pool.acquire(&[h + 2, w + 2]);
    let mut dy = pool.acquire(&[h + 2, w + 2]);
    let mut dxy = pool.acquire(&[h + 2, w + 2]);
    let mut out = pool.acquire(&[h, w]);
    corner_harris_core(img, k, &mut padded, &mut dx, &mut dy, &mut dxy, &mut out);
    pool.release(padded);
    pool.release(dx);
    pool.release(dy);
    pool.release(dxy);
    Ok(out)
}

/// The Harris body over caller-provided scratch: pad, fused valid Sobel
/// pair with products folded in, then fused window-sum + response (one
/// walk instead of three box convs plus an elementwise pass).  Every
/// phase shards into row bands per the ambient [`band_hint`].
fn corner_harris_core(
    img: &Mat,
    k: f32,
    padded: &mut Mat,
    dx: &mut Mat,
    dy: &mut Mat,
    dxy: &mut Mat,
    out: &mut Mat,
) {
    let (h, w) = (img.height(), img.width());
    let bands = band_hint();
    let simd = simd_enabled();
    edge_pad2_into(img, 2, padded); // (h+4, w+4)
    sobel_products_valid_into(padded, dx, dy, dxy, bands, simd); // (h+2, w+2)
    let wv = w + 2;
    let sxx = dx.as_slice();
    let syy = dy.as_slice();
    let sxy = dxy.as_slice();
    let dst = out.as_mut_slice();
    band_exec(dst, w, 0, h, bands, |y0, y1, chunk| {
        for y in y0..y1 {
            let drow = &mut chunk[(y - y0) * w..(y - y0 + 1) * w];
            harris_response_row(sxx, syy, sxy, wv, y, k, drow, simd);
        }
    });
}

/// One Harris response row: unnormalized 3x3 window sums of the three
/// gradient-product planes (full slices, padded width `wv`), then
/// `R = det(M) - k*trace(M)^2`.  Per-accumulator add order matches the
/// scalar triple-loop exactly.
#[inline]
fn harris_response_row(
    sxx: &[f32],
    syy: &[f32],
    sxy: &[f32],
    wv: usize,
    y: usize,
    k: f32,
    drow: &mut [f32],
    simd: bool,
) {
    let w = drow.len();
    let mut x = 0usize;
    if simd {
        let vk = F32x8::splat(k);
        while x + LANES <= w {
            let mut va = F32x8::splat(0.0);
            let mut vb = F32x8::splat(0.0);
            let mut vc = F32x8::splat(0.0);
            for d in 0..3 {
                let base = (y + d) * wv + x;
                va = va
                    + F32x8::load(&sxx[base..])
                    + F32x8::load(&sxx[base + 1..])
                    + F32x8::load(&sxx[base + 2..]);
                vb = vb
                    + F32x8::load(&syy[base..])
                    + F32x8::load(&syy[base + 1..])
                    + F32x8::load(&syy[base + 2..]);
                vc = vc
                    + F32x8::load(&sxy[base..])
                    + F32x8::load(&sxy[base + 1..])
                    + F32x8::load(&sxy[base + 2..]);
            }
            let tr = va + vb;
            (va * vb - vc * vc - vk * tr * tr).store(&mut drow[x..]);
            x += LANES;
        }
    }
    for x in x..w {
        let mut a = 0.0f32;
        let mut b = 0.0f32;
        let mut c = 0.0f32;
        for d in 0..3 {
            let base = (y + d) * wv + x;
            a += sxx[base];
            a += sxx[base + 1];
            a += sxx[base + 2];
            b += syy[base];
            b += syy[base + 1];
            b += syy[base + 2];
            c += sxy[base];
            c += sxy[base + 1];
            c += sxy[base + 2];
        }
        let tr = a + b;
        drow[x] = (a * b - c * c) - k * tr * tr;
    }
}

/// The fused gray→response mega-kernel: `cvtColor` + `cornerHarris` in
/// one call over pooled buffers.  The builder selects it when consecutive
/// software tasks cover the whole chain inside one stage, skipping the
/// intermediate gray buffer's trip through the frame environment.
/// Bit-for-bit identical to running the two kernels back to back.
pub fn harris_pipeline_pooled(rgb: &Mat, k: f32, pool: &BufferPool) -> Result<Mat> {
    let mut gray = pool.acquire(&[rgb.height(), rgb.width()]);
    cvt_color_into(rgb, &mut gray)?;
    let out = corner_harris_pooled(&gray, k, pool)?;
    pool.release(gray);
    Ok(out)
}

/// Pool-free [`harris_pipeline_pooled`] (the registry's plain fallback).
pub fn harris_pipeline(rgb: &Mat, k: f32) -> Result<Mat> {
    let gray = cvt_color(rgb)?;
    corner_harris(&gray, k)
}

/// Harris-Stephens response from precomputed gradient images —
/// the two-input fan-in of the DAG-shaped Harris flow (`gray →
/// {Sobel dx, Sobel dy} → response`).  Window sums use the same
/// unnormalized 3x3 box as [`corner_harris`], but over replicate-border
/// gradients the caller already produced: this is the *separated*
/// formulation, numerically distinct from the fused kernel at borders.
pub fn harris_response(ix: &Mat, iy: &Mat, k: f32) -> Result<Mat> {
    check_harris_response(ix, iy)?;
    let (h, w) = (ix.height(), ix.width());
    let mut bufs: Vec<Mat> = (0..6).map(|_| Mat::zeros(&[h, w])).collect();
    let mut out = Mat::zeros(&[h, w]);
    harris_response_core(ix, iy, k, &mut bufs, &mut out);
    Ok(out)
}

/// [`harris_response`] over pooled scratch.
pub fn harris_response_pooled(ix: &Mat, iy: &Mat, k: f32, pool: &BufferPool) -> Result<Mat> {
    check_harris_response(ix, iy)?;
    let (h, w) = (ix.height(), ix.width());
    let mut bufs: Vec<Mat> = (0..6).map(|_| pool.acquire(&[h, w])).collect();
    let mut out = pool.acquire(&[h, w]);
    harris_response_core(ix, iy, k, &mut bufs, &mut out);
    for b in bufs {
        pool.release(b);
    }
    Ok(out)
}

fn check_harris_response(ix: &Mat, iy: &Mat) -> Result<()> {
    expect_gray(ix, "harris_response")?;
    expect_gray(iy, "harris_response")?;
    if ix.shape() != iy.shape() {
        return Err(CourierError::ShapeMismatch {
            context: "harris_response".into(),
            expected: format!("{:?}", ix.shape()),
            got: format!("{:?}", iy.shape()),
        });
    }
    Ok(())
}

/// Body of [`harris_response`]: products, three replicate-border box
/// sums, response.  `bufs` must hold six (H, W) scratch buffers.
fn harris_response_core(ix: &Mat, iy: &Mat, k: f32, bufs: &mut [Mat], out: &mut Mat) {
    let (h, w) = (ix.height(), ix.width());
    let [pxx, pyy, pxy, sxx, syy, sxy] = bufs else {
        panic!("harris_response_core needs exactly 6 scratch buffers");
    };
    let bands = band_hint();
    {
        let xs = ix.as_slice();
        let ys = iy.as_slice();
        let (dxx, dyy, dxy) =
            (pxx.as_mut_slice(), pyy.as_mut_slice(), pxy.as_mut_slice());
        band_exec3(dxx, dyy, dxy, w, 0, h, bands, |y0, y1, cxx, cyy, cxy| {
            let off = y0 * w;
            for i in off..y1 * w {
                cxx[i - off] = xs[i] * xs[i];
                cyy[i - off] = ys[i] * ys[i];
                cxy[i - off] = xs[i] * ys[i];
            }
        });
    }
    let box3 = [[1.0f32; 3]; 3];
    conv3x3_into(pxx, &box3, sxx);
    conv3x3_into(pyy, &box3, syy);
    conv3x3_into(pxy, &box3, sxy);
    {
        let (a, b, c) = (sxx.as_slice(), syy.as_slice(), sxy.as_slice());
        let dst = out.as_mut_slice();
        band_exec(dst, w, 0, h, bands, |y0, y1, chunk| {
            let off = y0 * w;
            for i in off..y1 * w {
                let tr = a[i] + b[i];
                chunk[i - off] = (a[i] * b[i] - c[i] * c[i]) - k * tr * tr;
            }
        });
    }
}

/// Replicate-pad by `p` pixels on each spatial side into `out`
/// ((H+2p, W+2p)): interior rows are straight `memcpy`s, pads are fills.
fn edge_pad2_into(img: &Mat, p: usize, out: &mut Mat) {
    let (h, w) = (img.height(), img.width());
    let wp = w + 2 * p;
    debug_assert_eq!(out.shape(), &[h + 2 * p, wp]);
    let src = img.as_slice();
    let dst = out.as_mut_slice();
    band_exec(dst, wp, 0, h + 2 * p, band_hint(), |y0, y1, chunk| {
        for y in y0..y1 {
            let sy = (y as isize - p as isize).clamp(0, h as isize - 1) as usize;
            let srow = &src[sy * w..(sy + 1) * w];
            let drow = &mut chunk[(y - y0) * wp..(y - y0 + 1) * wp];
            drow[..p].fill(srow[0]);
            drow[p..p + w].copy_from_slice(srow);
            drow[p + w..].fill(srow[w - 1]);
        }
    });
}

/// Fused valid Sobel pair *with* gradient products: (H, W) ->
/// (H-2, W-2) planes `gx*gx`, `gy*gy`, `gx*gy` in one raw-slice walk
/// (no clamping anywhere — the input is already padded).  Folding the
/// products in saves a full read-modify-write sweep over three planes
/// versus the old separate squaring pass, and produces identical f32
/// values (same gradient expressions, then one multiply each).
fn sobel_products_valid_into(
    padded: &Mat,
    dxx: &mut Mat,
    dyy: &mut Mat,
    dxy: &mut Mat,
    bands: usize,
    simd: bool,
) {
    let ws = padded.width();
    let (h, w) = (padded.height() - 2, padded.width() - 2);
    debug_assert_eq!(dxx.shape(), &[h, w]);
    debug_assert_eq!(dyy.shape(), &[h, w]);
    debug_assert_eq!(dxy.shape(), &[h, w]);
    let src = padded.as_slice();
    let xs = dxx.as_mut_slice();
    let ys = dyy.as_mut_slice();
    let xy = dxy.as_mut_slice();
    band_exec3(xs, ys, xy, w, 0, h, bands, |y0, y1, cxx, cyy, cxy| {
        for y in y0..y1 {
            let r0 = &src[y * ws..y * ws + ws];
            let r1 = &src[(y + 1) * ws..(y + 1) * ws + ws];
            let r2 = &src[(y + 2) * ws..(y + 2) * ws + ws];
            let o = (y - y0) * w;
            sobel_products_row(
                r0,
                r1,
                r2,
                &mut cxx[o..o + w],
                &mut cyy[o..o + w],
                &mut cxy[o..o + w],
                simd,
            );
        }
    });
}

/// One valid-Sobel-plus-products row over a padded source (rows are
/// `w + 2` wide; reads are at `x`, `x+1`, `x+2`).
#[inline]
fn sobel_products_row(
    r0: &[f32],
    r1: &[f32],
    r2: &[f32],
    xrow: &mut [f32],
    yrow: &mut [f32],
    xyrow: &mut [f32],
    simd: bool,
) {
    let w = xrow.len();
    let mut x = 0usize;
    if simd {
        let two = F32x8::splat(2.0);
        while x + LANES <= w {
            let a = F32x8::load(&r0[x..]);
            let b = F32x8::load(&r0[x + 1..]);
            let c = F32x8::load(&r0[x + 2..]);
            let d = F32x8::load(&r1[x..]);
            let f = F32x8::load(&r1[x + 2..]);
            let g = F32x8::load(&r2[x..]);
            let hh = F32x8::load(&r2[x + 1..]);
            let i = F32x8::load(&r2[x + 2..]);
            let gx = -a + c - two * d + two * f - g + i;
            let gy = -a - two * b - c + g + two * hh + i;
            (gx * gx).store(&mut xrow[x..]);
            (gy * gy).store(&mut yrow[x..]);
            (gx * gy).store(&mut xyrow[x..]);
            x += LANES;
        }
    }
    for x in x..w {
        let (a, b, c) = (r0[x], r0[x + 1], r0[x + 2]);
        let (d, f) = (r1[x], r1[x + 2]);
        let (g, hh, i) = (r2[x], r2[x + 1], r2[x + 2]);
        let gx = -a + c - 2.0 * d + 2.0 * f - g + i;
        let gy = -a - 2.0 * b - c + g + 2.0 * hh + i;
        xrow[x] = gx * gx;
        yrow[x] = gy * gy;
        xyrow[x] = gx * gy;
    }
}

// ---------------------------------------------------------------------------
// elementwise ops (in-place variants: the builder routes through them
// when liveness says the input buffer dies)
// ---------------------------------------------------------------------------

/// Min-max normalize to `[alpha, beta]` — `cv::normalize(NORM_MINMAX)`.
pub fn normalize(img: &Mat, alpha: f32, beta: f32) -> Result<Mat> {
    let mut out = img.clone();
    normalize_mut(&mut out, alpha, beta)?;
    Ok(out)
}

/// In-place [`normalize`].
pub fn normalize_mut(img: &mut Mat, alpha: f32, beta: f32) -> Result<()> {
    expect_gray(img, "normalize")?;
    let (mn, mx) = (img.min(), img.max());
    let scale = (beta - alpha) / (mx - mn).max(1e-12);
    for v in img.as_mut_slice() {
        *v = (*v - mn) * scale + alpha;
    }
    Ok(())
}

/// `saturate_cast<uchar>(|alpha * x + beta|)` kept in f32 —
/// `cv::convertScaleAbs`.  OpenCV's saturate_cast rounds half-to-even,
/// and the rounding is semantically important: it makes the function a
/// genuine u8 quantization rather than a float identity.
pub fn convert_scale_abs(img: &Mat, alpha: f32, beta: f32) -> Result<Mat> {
    let mut out = img.clone();
    convert_scale_abs_mut(&mut out, alpha, beta)?;
    Ok(out)
}

/// In-place [`convert_scale_abs`].
pub fn convert_scale_abs_mut(img: &mut Mat, alpha: f32, beta: f32) -> Result<()> {
    expect_gray(img, "convert_scale_abs")?;
    for v in img.as_mut_slice() {
        *v = round_half_even((alpha * *v + beta).abs()).min(255.0);
    }
    Ok(())
}

/// Round to nearest, ties to even (matches `jnp.round` / IEEE-754
/// roundTiesToEven, which the Pallas kernel lowers to).
fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - (r - x).signum()
    } else {
        r
    }
}

/// Binary threshold — `cv::threshold(THRESH_BINARY)`.
pub fn threshold(img: &Mat, thresh: f32, maxval: f32) -> Result<Mat> {
    let mut out = img.clone();
    threshold_mut(&mut out, thresh, maxval)?;
    Ok(out)
}

/// In-place [`threshold`].
pub fn threshold_mut(img: &mut Mat, thresh: f32, maxval: f32) -> Result<()> {
    expect_gray(img, "threshold")?;
    for v in img.as_mut_slice() {
        *v = if *v > thresh { maxval } else { 0.0 };
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// parity oracle
// ---------------------------------------------------------------------------

/// The pre-optimization kernels, kept verbatim as the parity reference.
///
/// Every per-pixel arithmetic sequence here is what the fast paths above
/// must reproduce; `tests/kernel_parity.rs` asserts the match across
/// randomized shapes including 1×1, 1×N and N×1 degenerate images.
pub mod reference {
    use super::{
        expect_gray, round_half_even, CourierError, Mat, Result, GAUSS3, LAPLACIAN, SCHARR_DX,
        SOBEL_DX, SOBEL_DY,
    };

    /// Naive 3x3 convolution: clamped loads, per-tap zero check.
    pub fn conv3x3(img: &Mat, taps: &[[f32; 3]; 3]) -> Mat {
        let (h, w) = (img.height(), img.width());
        let mut out = Mat::zeros(&[h, w]);
        let dst = out.as_mut_slice();
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0.0f32;
                for (dy, row) in taps.iter().enumerate() {
                    for (dx, &t) in row.iter().enumerate() {
                        if t == 0.0 {
                            continue;
                        }
                        acc += t
                            * img.at2_clamped(
                                y as isize + dy as isize - 1,
                                x as isize + dx as isize - 1,
                            );
                    }
                }
                dst[y * w + x] = acc;
            }
        }
        out
    }

    /// Naive `cv::Sobel`.
    pub fn sobel(img: &Mat, dx: u8, dy: u8) -> Result<Mat> {
        expect_gray(img, "sobel")?;
        match (dx, dy) {
            (1, 0) => Ok(conv3x3(img, &SOBEL_DX)),
            (0, 1) => Ok(conv3x3(img, &SOBEL_DY)),
            _ => Err(CourierError::Other("sobel: exactly one of dx/dy must be 1".into())),
        }
    }

    /// Naive 2-D `cv::GaussianBlur(3x3)`.
    pub fn gaussian_blur(img: &Mat) -> Result<Mat> {
        expect_gray(img, "gaussian_blur")?;
        Ok(conv3x3(img, &GAUSS3))
    }

    /// Naive `cv::boxFilter`.
    pub fn box_filter(img: &Mat, normalize: bool) -> Result<Mat> {
        expect_gray(img, "box_filter")?;
        let t = if normalize { 1.0 / 9.0 } else { 1.0 };
        Ok(conv3x3(img, &[[t; 3]; 3]))
    }

    /// Naive `cv::Laplacian`.
    pub fn laplacian(img: &Mat) -> Result<Mat> {
        expect_gray(img, "laplacian")?;
        Ok(conv3x3(img, &LAPLACIAN))
    }

    /// Naive `cv::Scharr`.
    pub fn scharr(img: &Mat) -> Result<Mat> {
        expect_gray(img, "scharr")?;
        Ok(conv3x3(img, &SCHARR_DX))
    }

    /// Naive `cv::medianBlur(3)`.
    pub fn median_blur(img: &Mat) -> Result<Mat> {
        expect_gray(img, "median_blur")?;
        let (h, w) = (img.height(), img.width());
        let mut out = Mat::zeros(&[h, w]);
        let dst = out.as_mut_slice();
        let mut window = [0.0f32; 9];
        for y in 0..h {
            for x in 0..w {
                let mut k = 0;
                for dy in -1isize..=1 {
                    for dx in -1isize..=1 {
                        window[k] = img.at2_clamped(y as isize + dy, x as isize + dx);
                        k += 1;
                    }
                }
                for i in 0..=4 {
                    let mut min_i = i;
                    for j in i + 1..9 {
                        if window[j] < window[min_i] {
                            min_i = j;
                        }
                    }
                    window.swap(i, min_i);
                }
                dst[y * w + x] = window[4];
            }
        }
        Ok(out)
    }

    fn morph(img: &Mat, op: fn(f32, f32) -> f32) -> Mat {
        let (h, w) = (img.height(), img.width());
        let mut out = Mat::zeros(&[h, w]);
        let dst = out.as_mut_slice();
        for y in 0..h {
            for x in 0..w {
                let mut acc = img.at2_clamped(y as isize - 1, x as isize - 1);
                for dy in 0..3isize {
                    for dx in 0..3isize {
                        acc = op(acc, img.at2_clamped(y as isize + dy - 1, x as isize + dx - 1));
                    }
                }
                dst[y * w + x] = acc;
            }
        }
        out
    }

    /// Naive `cv::erode`.
    pub fn erode(img: &Mat) -> Result<Mat> {
        expect_gray(img, "erode")?;
        Ok(morph(img, f32::min))
    }

    /// Naive `cv::dilate`.
    pub fn dilate(img: &Mat) -> Result<Mat> {
        expect_gray(img, "dilate")?;
        Ok(morph(img, f32::max))
    }

    /// Replicate-pad by `p` pixels on each spatial side.
    fn edge_pad2(img: &Mat, p: usize) -> Mat {
        let (h, w) = (img.height(), img.width());
        let mut out = Mat::zeros(&[h + 2 * p, w + 2 * p]);
        let dst = out.as_mut_slice();
        let wp = w + 2 * p;
        for y in 0..h + 2 * p {
            for x in 0..wp {
                dst[y * wp + x] =
                    img.at2_clamped(y as isize - p as isize, x as isize - p as isize);
            }
        }
        out
    }

    /// Valid naive 3x3 convolution: (H, W) -> (H-2, W-2).
    fn conv3x3_valid(img: &Mat, taps: &[[f32; 3]; 3]) -> Mat {
        let (h, w) = (img.height() - 2, img.width() - 2);
        let src = img.as_slice();
        let ws = img.width();
        let mut out = Mat::zeros(&[h, w]);
        let dst = out.as_mut_slice();
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0.0f32;
                for (dy, row) in taps.iter().enumerate() {
                    for (dx, &t) in row.iter().enumerate() {
                        if t == 0.0 {
                            continue;
                        }
                        acc += t * src[(y + dy) * ws + (x + dx)];
                    }
                }
                dst[y * w + x] = acc;
            }
        }
        out
    }

    /// Naive `cv::cornerHarris` (pad, two valid Sobels, products, three
    /// valid box sums, response — each stage its own full image pass).
    pub fn corner_harris(img: &Mat, k: f32) -> Result<Mat> {
        expect_gray(img, "corner_harris")?;
        let (h, w) = (img.height(), img.width());
        let padded = edge_pad2(img, 2); // (h+4, w+4)
        let dx = conv3x3_valid(&padded, &SOBEL_DX); // (h+2, w+2)
        let dy = conv3x3_valid(&padded, &SOBEL_DY);
        let n = dx.len();
        let mut dxx = Mat::zeros(&[h + 2, w + 2]);
        let mut dyy = Mat::zeros(&[h + 2, w + 2]);
        let mut dxy = Mat::zeros(&[h + 2, w + 2]);
        {
            let (xs, ys) = (dx.as_slice(), dy.as_slice());
            let (pxx, pyy, pxy) =
                (dxx.as_mut_slice(), dyy.as_mut_slice(), dxy.as_mut_slice());
            for i in 0..n {
                pxx[i] = xs[i] * xs[i];
                pyy[i] = ys[i] * ys[i];
                pxy[i] = xs[i] * ys[i];
            }
        }
        let box3 = [[1.0f32; 3]; 3];
        let sxx = conv3x3_valid(&dxx, &box3); // (h, w)
        let syy = conv3x3_valid(&dyy, &box3);
        let sxy = conv3x3_valid(&dxy, &box3);
        let mut out = Mat::zeros(&[h, w]);
        {
            let (a, b, c) = (sxx.as_slice(), syy.as_slice(), sxy.as_slice());
            let dst = out.as_mut_slice();
            for i in 0..h * w {
                let tr = a[i] + b[i];
                dst[i] = (a[i] * b[i] - c[i] * c[i]) - k * tr * tr;
            }
        }
        Ok(out)
    }

    /// Naive two-input Harris response.
    pub fn harris_response(ix: &Mat, iy: &Mat, k: f32) -> Result<Mat> {
        super::check_harris_response(ix, iy)?;
        let (h, w) = (ix.height(), ix.width());
        let mut pxx = Mat::zeros(&[h, w]);
        let mut pyy = Mat::zeros(&[h, w]);
        let mut pxy = Mat::zeros(&[h, w]);
        {
            let (xs, ys) = (ix.as_slice(), iy.as_slice());
            let (dxx, dyy, dxy) =
                (pxx.as_mut_slice(), pyy.as_mut_slice(), pxy.as_mut_slice());
            for i in 0..h * w {
                dxx[i] = xs[i] * xs[i];
                dyy[i] = ys[i] * ys[i];
                dxy[i] = xs[i] * ys[i];
            }
        }
        let box3 = [[1.0f32; 3]; 3];
        let sxx = conv3x3(&pxx, &box3);
        let syy = conv3x3(&pyy, &box3);
        let sxy = conv3x3(&pxy, &box3);
        let mut out = Mat::zeros(&[h, w]);
        {
            let (a, b, c) = (sxx.as_slice(), syy.as_slice(), sxy.as_slice());
            let dst = out.as_mut_slice();
            for i in 0..h * w {
                let tr = a[i] + b[i];
                dst[i] = (a[i] * b[i] - c[i] * c[i]) - k * tr * tr;
            }
        }
        Ok(out)
    }

    /// Naive elementwise ops (allocate-then-transform clones).
    pub fn normalize(img: &Mat, alpha: f32, beta: f32) -> Result<Mat> {
        expect_gray(img, "normalize")?;
        let (mn, mx) = (img.min(), img.max());
        let scale = (beta - alpha) / (mx - mn).max(1e-12);
        let mut out = img.clone();
        for v in out.as_mut_slice() {
            *v = (*v - mn) * scale + alpha;
        }
        Ok(out)
    }

    /// Naive `cv::convertScaleAbs`.
    pub fn convert_scale_abs(img: &Mat, alpha: f32, beta: f32) -> Result<Mat> {
        expect_gray(img, "convert_scale_abs")?;
        let mut out = img.clone();
        for v in out.as_mut_slice() {
            *v = round_half_even((alpha * *v + beta).abs()).min(255.0);
        }
        Ok(out)
    }

    /// Naive `cv::threshold`.
    pub fn threshold(img: &Mat, thresh: f32, maxval: f32) -> Result<Mat> {
        expect_gray(img, "threshold")?;
        let mut out = img.clone();
        for v in out.as_mut_slice() {
            *v = if *v > thresh { maxval } else { 0.0 };
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn cvt_color_known_value() {
        let mut img = Mat::zeros(&[1, 1, 3]);
        img.as_mut_slice().copy_from_slice(&[100.0, 0.0, 0.0]);
        let g = cvt_color(&img).unwrap();
        assert!((g.at2(0, 0) - 29.9).abs() < 1e-4);
    }

    #[test]
    fn cvt_color_rejects_gray_input() {
        assert!(cvt_color(&Mat::zeros(&[4, 4])).is_err());
    }

    #[test]
    fn sobel_constant_is_zero() {
        let img = Mat::full(&[6, 7], 42.0);
        let g = sobel(&img, 1, 0).unwrap();
        assert_eq!(g.max_abs_diff(&Mat::zeros(&[6, 7])), 0.0);
    }

    #[test]
    fn sobel_rejects_bad_derivative_order() {
        let img = Mat::zeros(&[4, 4]);
        assert!(sobel(&img, 1, 1).is_err());
        assert!(sobel(&img, 0, 0).is_err());
    }

    #[test]
    fn sobel_detects_vertical_edge() {
        // columns 0..2 dark, 2.. bright: dx response peaks at the edge.
        let mut img = Mat::zeros(&[5, 6]);
        for y in 0..5 {
            for x in 2..6 {
                img.set2(y, x, 200.0);
            }
        }
        let g = sobel(&img, 1, 0).unwrap();
        assert!(g.at2(2, 2) > 0.0);
        assert_eq!(g.at2(2, 4), 0.0); // interior of the flat region
    }

    #[test]
    fn sobel_xy_matches_split_kernels() {
        let img = synth::noise_gray(11, 13, 7);
        let mut dx = Mat::zeros(img.shape());
        let mut dy = Mat::zeros(img.shape());
        sobel_xy_into(&img, &mut dx, &mut dy).unwrap();
        assert_eq!(dx, sobel(&img, 1, 0).unwrap());
        assert_eq!(dy, sobel(&img, 0, 1).unwrap());
    }

    #[test]
    fn gaussian_preserves_constant() {
        let img = Mat::full(&[5, 5], 10.0);
        let g = gaussian_blur(&img).unwrap();
        assert!(g.max_abs_diff(&img) < 1e-4);
    }

    #[test]
    fn gaussian_separable_tracks_2d_reference() {
        for (h, w) in [(1usize, 1usize), (1, 7), (7, 1), (9, 12)] {
            let img = synth::noise_gray(h, w, 3);
            let sep = gaussian_blur(&img).unwrap();
            let full = reference::gaussian_blur(&img).unwrap();
            assert!(
                sep.allclose(&full, 1e-6, 1e-4),
                "({h}, {w}): max diff {}",
                sep.max_abs_diff(&full)
            );
        }
    }

    #[test]
    fn box_mean_of_constant() {
        let img = Mat::full(&[4, 4], 9.0);
        let g = box_filter(&img, true).unwrap();
        assert!(g.max_abs_diff(&img) < 1e-4);
        let s = box_filter(&img, false).unwrap();
        assert!((s.at2(1, 1) - 81.0).abs() < 1e-3);
    }

    #[test]
    fn erode_le_input_le_dilate() {
        let img = synth::noise_gray(12, 9, 3);
        let er = erode(&img).unwrap();
        let di = dilate(&img).unwrap();
        for y in 0..12 {
            for x in 0..9 {
                assert!(er.at2(y, x) <= img.at2(y, x));
                assert!(di.at2(y, x) >= img.at2(y, x));
            }
        }
    }

    #[test]
    fn pyr_down_halves_shape_and_preserves_constant() {
        let img = Mat::full(&[9, 12], 10.0);
        let half = pyr_down(&img).unwrap();
        assert_eq!(half.shape(), &[5, 6]);
        assert!(half.max_abs_diff(&Mat::full(&[5, 6], 10.0)) < 1e-4);
        // even-index decimation of the blurred image, exactly
        let blurred = gaussian_blur(&synth::noise_gray(9, 12, 11)).unwrap();
        let half = pyr_down(&synth::noise_gray(9, 12, 11)).unwrap();
        for y in 0..5 {
            for x in 0..6 {
                assert_eq!(half.at2(y, x), blurred.at2(2 * y, 2 * x));
            }
        }
    }

    #[test]
    fn pyr_down_pooled_matches_plain_bitwise() {
        let pool = BufferPool::new();
        for (h, w) in [(1usize, 1usize), (1, 7), (8, 8), (9, 11)] {
            let img = synth::noise_gray(h, w, 13);
            let plain = pyr_down(&img).unwrap();
            let pooled = pyr_down_pooled(&img, &pool).unwrap();
            assert_eq!(plain, pooled, "({h}, {w})");
            pool.release(pooled);
        }
    }

    #[test]
    fn erode_dilate_pair_matches_split_kernels() {
        for (h, w) in [(1usize, 1usize), (2, 9), (3, 3), (12, 17)] {
            let img = synth::noise_gray(h, w, 7);
            let mut er = Mat::zeros(img.shape());
            let mut di = Mat::zeros(img.shape());
            erode_dilate_into(&img, &mut er, &mut di).unwrap();
            assert_eq!(er, erode(&img).unwrap(), "({h}, {w}) erode leg");
            assert_eq!(di, dilate(&img).unwrap(), "({h}, {w}) dilate leg");
        }
    }

    #[test]
    fn harris_flat_is_zero_and_corner_fires() {
        let flat = Mat::full(&[8, 8], 100.0);
        let r = corner_harris(&flat, HARRIS_K).unwrap();
        assert!(r.max_abs_diff(&Mat::zeros(&[8, 8])) < 1e-2);

        let mut quad = Mat::zeros(&[16, 16]);
        for y in 8..16 {
            for x in 8..16 {
                quad.set2(y, x, 255.0);
            }
        }
        let r = corner_harris(&quad, HARRIS_K).unwrap();
        // strongest |response| near (8, 8)
        let mut best = (0usize, 0usize, 0.0f32);
        for y in 0..16 {
            for x in 0..16 {
                let v = r.at2(y, x).abs();
                if v > best.2 {
                    best = (y, x, v);
                }
            }
        }
        assert!(best.0.abs_diff(8) <= 2 && best.1.abs_diff(8) <= 2, "peak at {best:?}");
    }

    #[test]
    fn harris_matches_naive_reference_bit_for_bit() {
        for (h, w) in [(1usize, 1usize), (1, 6), (6, 1), (13, 17)] {
            let img = synth::noise_gray(h, w, 5);
            let fast = corner_harris(&img, HARRIS_K).unwrap();
            let naive = reference::corner_harris(&img, HARRIS_K).unwrap();
            assert_eq!(fast, naive, "({h}, {w})");
        }
    }

    #[test]
    fn harris_pipeline_matches_two_kernel_chain() {
        let pool = BufferPool::new();
        let rgb = synth::noise_rgb(10, 14, 9);
        let fused = harris_pipeline_pooled(&rgb, HARRIS_K, &pool).unwrap();
        let gray = cvt_color(&rgb).unwrap();
        let chain = corner_harris(&gray, HARRIS_K).unwrap();
        assert_eq!(fused, chain);
        assert_eq!(harris_pipeline(&rgb, HARRIS_K).unwrap(), chain);
    }

    #[test]
    fn harris_response_flat_is_zero_and_rejects_mismatch() {
        let zx = Mat::zeros(&[8, 8]);
        let zy = Mat::zeros(&[8, 8]);
        let r = harris_response(&zx, &zy, HARRIS_K).unwrap();
        assert_eq!(r.max_abs_diff(&Mat::zeros(&[8, 8])), 0.0);
        assert!(harris_response(&zx, &Mat::zeros(&[4, 4]), HARRIS_K).is_err());

        // corner-ish gradients produce a nonzero response somewhere
        let img = synth::noise_gray(12, 12, 9);
        let ix = sobel(&img, 1, 0).unwrap();
        let iy = sobel(&img, 0, 1).unwrap();
        let r = harris_response(&ix, &iy, HARRIS_K).unwrap();
        assert!(r.as_slice().iter().any(|v| v.abs() > 0.0));
    }

    #[test]
    fn pooled_banded_gaussian_matches_two_pass_bitwise() {
        use super::super::banding::set_bands;
        let pool = BufferPool::new();
        for (h, w) in [(1usize, 9usize), (3, 9), (16, 9), (17, 5)] {
            let img = synth::noise_gray(h, w, 11);
            let plain = gaussian_blur(&img).unwrap();
            for bands in [1usize, 2, 3, 8] {
                let _g = set_bands(bands);
                let banded = gaussian_blur_pooled(&img, &pool).unwrap();
                assert_eq!(banded, plain, "({h}, {w}) bands={bands}");
                pool.release(banded);
            }
        }
        // steady state: the overlapped tiles recycle through the parent
        // frame's capacity class instead of minting per-band shelves
        let img = synth::noise_gray(16, 9, 2);
        {
            let _g = set_bands(4);
            let a = gaussian_blur_pooled(&img, &pool).unwrap();
            pool.release(a);
            let warm = pool.stats().misses;
            for _ in 0..5 {
                let b = gaussian_blur_pooled(&img, &pool).unwrap();
                pool.release(b);
            }
            assert_eq!(pool.stats().misses, warm, "banded scratch must recycle");
        }
    }

    #[test]
    fn laplacian_flat_is_zero() {
        let img = Mat::full(&[6, 6], 50.0);
        let l = laplacian(&img).unwrap();
        assert!(l.max_abs_diff(&Mat::zeros(&[6, 6])) < 1e-4);
    }

    #[test]
    fn scharr_vertical_edge_responds() {
        let mut img = Mat::zeros(&[5, 6]);
        for y in 0..5 {
            for x in 3..6 {
                img.set2(y, x, 100.0);
            }
        }
        let s = scharr(&img).unwrap();
        assert!(s.at2(2, 2) > 0.0); // left of the edge sees +dx
        assert_eq!(s.at2(2, 0), 0.0); // flat region
    }

    #[test]
    fn median_removes_salt_noise() {
        let mut img = Mat::full(&[5, 5], 10.0);
        img.set2(2, 2, 255.0); // single hot pixel
        let m = median_blur(&img).unwrap();
        assert_eq!(m.at2(2, 2), 10.0);
        // median of a constant neighborhood stays constant
        assert_eq!(m.at2(0, 0), 10.0);
    }

    #[test]
    fn median_of_sorted_values() {
        // 3x3 with distinct values: center output is the true median
        let img = Mat::new(vec![3, 3], vec![9.0, 1.0, 8.0, 2.0, 7.0, 3.0, 6.0, 4.0, 5.0]).unwrap();
        let m = median_blur(&img).unwrap();
        assert_eq!(m.at2(1, 1), 5.0);
    }

    #[test]
    fn normalize_hits_bounds() {
        let img = synth::noise_gray(10, 10, 5);
        let n = normalize(&img, 0.0, 255.0).unwrap();
        assert!((n.min() - 0.0).abs() < 1e-3);
        assert!((n.max() - 255.0).abs() < 1e-3);
    }

    #[test]
    fn normalize_constant_input_is_finite() {
        let img = Mat::full(&[3, 3], 7.0);
        let n = normalize(&img, 0.0, 255.0).unwrap();
        assert!(n.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn convert_scale_abs_saturates() {
        let img = Mat::new(vec![1, 3], vec![-300.0, -10.0, 400.0]).unwrap();
        let c = convert_scale_abs(&img, 1.0, 0.0).unwrap();
        assert_eq!(c.as_slice(), &[255.0, 10.0, 255.0]);
    }

    #[test]
    fn threshold_binary() {
        let img = Mat::new(vec![1, 3], vec![10.0, 127.0, 128.0]).unwrap();
        let t = threshold(&img, 127.0, 255.0).unwrap();
        assert_eq!(t.as_slice(), &[0.0, 0.0, 255.0]);
    }

    #[test]
    fn inplace_variants_match_allocating_ones() {
        let img = synth::noise_gray(6, 6, 2);
        let mut a = img.clone();
        threshold_mut(&mut a, 100.0, 255.0).unwrap();
        assert_eq!(a, threshold(&img, 100.0, 255.0).unwrap());
        let mut b = img.clone();
        normalize_mut(&mut b, 0.0, 255.0).unwrap();
        assert_eq!(b, normalize(&img, 0.0, 255.0).unwrap());
        let mut c = img.clone();
        convert_scale_abs_mut(&mut c, 1.0, 0.0).unwrap();
        assert_eq!(c, convert_scale_abs(&img, 1.0, 0.0).unwrap());
    }
}
