//! The software function library (the "OpenCV + BLAS" the target binary
//! links against).
//!
//! Every function here is a faithful Rust port of the pure-jnp oracle in
//! `python/compile/kernels/ref.py`, so CPU (software task) and accelerator
//! (hardware module) paths of a mixed pipeline are numerically
//! interchangeable — the property the Function Off-loader depends on when
//! it swaps implementations under a running binary.
//!
//! The [`Registry`] is the dynamic-linking substrate: the app interpreter
//! resolves call symbols (`cv::cvtColor`, `blas::sgemm`, ...) through it,
//! and the off-loader patches resolutions the same way DLL injection
//! rebinds `dlsym` lookups in the paper.

pub mod banding;
pub mod blas;
pub mod imgproc;
mod registry;
pub mod simd;

pub use registry::{
    FuncEntry, PairEntry, Registry, ScalarEntry, SwFn, SwFnInPlace, SwFnPair, SwFnPooled,
    SwFnScalar, SwFnScalarPooled, FUSED_CVT_HARRIS, FUSED_MORPH_PAIR, FUSED_SOBEL_PAIR,
};
