//! Symbol registry — the dynamic-linker substrate.
//!
//! The interpreter resolves every call through a [`Registry`]; the
//! Function Off-loader later *re-binds* symbols in a separate hook table
//! (see `offload::HookTable`), so the registry itself always answers with
//! the original library function — the paper's `dlsym(RTLD_NEXT, ...)`.
//!
//! Besides the plain callable, an entry may carry two hot-path variants
//! the pipeline builder routes through when it can prove they are safe:
//!
//! * a **pooled** form (`Fn(&[&Mat], &BufferPool) -> Mat`) that draws its
//!   output and scratch from the pipeline's shape-keyed buffer pool, and
//! * an **in-place** form (`Fn(Mat) -> Mat`) for unary elementwise ops,
//!   used when liveness says the input buffer dies at this call.
//!
//! Both must be numerically identical to the plain callable (the kernel
//! parity suite pins this); the interpreter and tracer always use the
//! plain form, so traces stay independent of pipeline execution details.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::image::Mat;
use crate::pipeline::BufferPool;
use crate::{CourierError, Result};

use super::{blas, imgproc};

/// A library function: a boxed pure function over `Mat` arguments.
pub type SwFn = Arc<dyn Fn(&[&Mat]) -> Result<Mat> + Send + Sync>;

/// Pool-aware variant: output (and any scratch) comes from the pool.
pub type SwFnPooled = Arc<dyn Fn(&[&Mat], &BufferPool) -> Result<Mat> + Send + Sync>;

/// In-place variant for unary elementwise ops: consumes the (dead) input
/// buffer and returns it transformed.
pub type SwFnInPlace = Arc<dyn Fn(Mat) -> Result<Mat> + Send + Sync>;

/// The fused gray→response mega-kernel the builder selects when
/// consecutive software tasks cover the whole `cvtColor → cornerHarris`
/// chain inside one stage (same naming convention as the AOT module
/// catalog's fused hardware entry).
pub const FUSED_CVT_HARRIS: &str = "cv::cvtColor+cv::cornerHarris";

/// Label of the fused one-walk Sobel dx+dy pair the builder selects when
/// a fork-join stage holds exactly the two sibling gradients over one
/// shared input ([`imgproc::sobel_xy_into`]).
pub const FUSED_SOBEL_PAIR: &str = "cv::Sobel+cv::SobelY";

/// One resolvable library symbol.
#[derive(Clone)]
pub struct FuncEntry {
    /// Fully qualified symbol, e.g. `cv::cornerHarris`.
    pub symbol: String,
    /// Number of `Mat` arguments.
    pub arity: usize,
    /// The callable.
    pub f: SwFn,
    /// Optional pool-aware form (same numerics, pooled buffers).
    pub pooled: Option<SwFnPooled>,
    /// Optional in-place form (same numerics, reuses the input buffer).
    pub inplace: Option<SwFnInPlace>,
    /// For a fused mega-kernel: the exact callables it composes, in
    /// chain order.  The builder only selects the fused binding while
    /// the live registry still resolves the constituent symbols to these
    /// same `Arc`s — re-registering either constituent (the override
    /// pattern) silently disables fusion instead of bypassing the
    /// override.
    pub fused_of: Option<Vec<SwFn>>,
}

impl FuncEntry {
    /// True iff this entry is a fused kernel whose constituents are
    /// exactly `parts` (pointer identity on the callables).
    pub fn fuses_exactly(&self, parts: &[&FuncEntry]) -> bool {
        match &self.fused_of {
            Some(own) => {
                own.len() == parts.len()
                    && own.iter().zip(parts).all(|(a, b)| Arc::ptr_eq(a, &b.f))
            }
            None => false,
        }
    }
}

impl std::fmt::Debug for FuncEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FuncEntry")
            .field("symbol", &self.symbol)
            .field("arity", &self.arity)
            .field("pooled", &self.pooled.is_some())
            .field("inplace", &self.inplace.is_some())
            .finish()
    }
}

/// The function library a target binary links against.
#[derive(Clone, Default)]
pub struct Registry {
    map: BTreeMap<String, FuncEntry>,
    /// The standard Sobel dx/dy callables recorded by [`Registry::standard`]
    /// — the identity link [`Registry::sobel_pair_intact`] checks before
    /// the builder may substitute the fused one-walk pair.
    sobel_pair: Option<(SwFn, SwFn)>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("symbols", &self.map.keys().collect::<Vec<_>>())
            .field("sobel_pair", &self.sobel_pair.is_some())
            .finish()
    }
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The standard library: every OpenCV/BLAS function the case-study
    /// binaries call, with the demo parameters baked in (blockSize=3,
    /// ksize=3, k=0.04 for Harris; alpha=1, beta=0 for convertScaleAbs;
    /// ... — identical to the AOT module catalog in `python/compile`).
    pub fn standard() -> Self {
        use imgproc::HARRIS_K;
        let mut r = Self::new();
        // the cvt/harris callables are bound to locals so the fused
        // mega-kernel can record exactly which implementations it fuses
        let cvt_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0]));
        let harris_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::corner_harris(a[0], HARRIS_K));
        let sobel_dx_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 1, 0));
        let sobel_dy_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 0, 1));
        r.register("cv::cvtColor", 1, cvt_f.clone());
        r.register("cv::Sobel", 1, sobel_dx_f.clone());
        r.register("cv::SobelY", 1, sobel_dy_f.clone());
        r.sobel_pair = Some((sobel_dx_f, sobel_dy_f));
        r.register("cv::GaussianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::gaussian_blur(a[0])));
        r.register("cv::boxFilter", 1, Arc::new(|a: &[&Mat]| imgproc::box_filter(a[0], true)));
        r.register("cv::erode", 1, Arc::new(|a: &[&Mat]| imgproc::erode(a[0])));
        r.register("cv::dilate", 1, Arc::new(|a: &[&Mat]| imgproc::dilate(a[0])));
        r.register("cv::Laplacian", 1, Arc::new(|a: &[&Mat]| imgproc::laplacian(a[0])));
        r.register("cv::Scharr", 1, Arc::new(|a: &[&Mat]| imgproc::scharr(a[0])));
        r.register("cv::medianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::median_blur(a[0])));
        r.register("cv::cornerHarris", 1, harris_f.clone());
        r.register(
            "cv::harrisResponse",
            2,
            Arc::new(|a: &[&Mat]| imgproc::harris_response(a[0], a[1], HARRIS_K)),
        );
        r.register(
            "cv::normalize",
            1,
            Arc::new(|a: &[&Mat]| imgproc::normalize(a[0], 0.0, 255.0)),
        );
        r.register(
            "cv::convertScaleAbs",
            1,
            Arc::new(|a: &[&Mat]| imgproc::convert_scale_abs(a[0], 1.0, 0.0)),
        );
        r.register(
            "cv::threshold",
            1,
            Arc::new(|a: &[&Mat]| imgproc::threshold(a[0], 127.0, 255.0)),
        );
        r.register(
            FUSED_CVT_HARRIS,
            1,
            Arc::new(|a: &[&Mat]| imgproc::harris_pipeline(a[0], HARRIS_K)),
        );
        r.set_fused_of(FUSED_CVT_HARRIS, vec![cvt_f, harris_f]);
        r.register("blas::sgemm", 2, Arc::new(|a: &[&Mat]| blas::sgemm(a[0], a[1])));
        r.register("blas::saxpy", 2, Arc::new(|a: &[&Mat]| blas::saxpy(1.0, a[0], a[1])));
        r.register("blas::sdot", 2, Arc::new(|a: &[&Mat]| blas::sdot(a[0], a[1])));

        // ---- pooled forms (output + scratch from the buffer pool) -----
        r.set_pooled(
            "cv::cvtColor",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire(&[a[0].height(), a[0].width()]);
                imgproc::cvt_color_into(a[0], &mut out)?;
                Ok(out)
            }),
        );
        r.set_pooled("cv::Sobel", pooled_unary(|img, out| imgproc::sobel_into(img, 1, 0, out)));
        r.set_pooled("cv::SobelY", pooled_unary(|img, out| imgproc::sobel_into(img, 0, 1, out)));
        r.set_pooled(
            "cv::GaussianBlur",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut tmp = p.acquire(a[0].shape());
                let mut out = p.acquire(a[0].shape());
                let res = imgproc::gaussian_blur_into(a[0], &mut tmp, &mut out);
                p.release(tmp);
                res.map(|()| out)
            }),
        );
        r.set_pooled("cv::boxFilter", pooled_unary(|img, out| imgproc::box_filter_into(img, true, out)));
        r.set_pooled("cv::erode", pooled_unary(imgproc::erode_into));
        r.set_pooled("cv::dilate", pooled_unary(imgproc::dilate_into));
        r.set_pooled("cv::Laplacian", pooled_unary(imgproc::laplacian_into));
        r.set_pooled("cv::Scharr", pooled_unary(imgproc::scharr_into));
        r.set_pooled("cv::medianBlur", pooled_unary(imgproc::median_blur_into));
        r.set_pooled(
            "cv::cornerHarris",
            Arc::new(|a: &[&Mat], p: &BufferPool| imgproc::corner_harris_pooled(a[0], HARRIS_K, p)),
        );
        r.set_pooled(
            "cv::harrisResponse",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                imgproc::harris_response_pooled(a[0], a[1], HARRIS_K, p)
            }),
        );
        r.set_pooled(
            FUSED_CVT_HARRIS,
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                imgproc::harris_pipeline_pooled(a[0], HARRIS_K, p)
            }),
        );
        r.set_pooled(
            "cv::normalize",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::normalize_mut(&mut out, 0.0, 255.0)?;
                Ok(out)
            }),
        );
        r.set_pooled(
            "cv::convertScaleAbs",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::convert_scale_abs_mut(&mut out, 1.0, 0.0)?;
                Ok(out)
            }),
        );
        r.set_pooled(
            "cv::threshold",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::threshold_mut(&mut out, 127.0, 255.0)?;
                Ok(out)
            }),
        );

        // ---- in-place forms (input buffer dies at the call) -----------
        r.set_inplace(
            "cv::normalize",
            Arc::new(|mut m: Mat| {
                imgproc::normalize_mut(&mut m, 0.0, 255.0)?;
                Ok(m)
            }),
        );
        r.set_inplace(
            "cv::convertScaleAbs",
            Arc::new(|mut m: Mat| {
                imgproc::convert_scale_abs_mut(&mut m, 1.0, 0.0)?;
                Ok(m)
            }),
        );
        r.set_inplace(
            "cv::threshold",
            Arc::new(|mut m: Mat| {
                imgproc::threshold_mut(&mut m, 127.0, 255.0)?;
                Ok(m)
            }),
        );
        r
    }

    /// Register (or replace) a symbol.
    pub fn register(&mut self, symbol: &str, arity: usize, f: SwFn) {
        self.map.insert(
            symbol.to_string(),
            FuncEntry {
                symbol: symbol.to_string(),
                arity,
                f,
                pooled: None,
                inplace: None,
                fused_of: None,
            },
        );
    }

    /// Declare an already-registered symbol as a fused kernel composing
    /// exactly `parts` (in chain order).
    pub fn set_fused_of(&mut self, symbol: &str, parts: Vec<SwFn>) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.fused_of = Some(parts);
        }
    }

    /// True while `cv::Sobel`/`cv::SobelY` still resolve to the standard
    /// kernels recorded at [`Registry::standard`] time — the builder's
    /// gate for substituting the fused one-walk Sobel pair
    /// ([`FUSED_SOBEL_PAIR`]); re-registering either symbol disables it.
    pub fn sobel_pair_intact(&self) -> bool {
        match &self.sobel_pair {
            Some((dx, dy)) => {
                self.map.get("cv::Sobel").is_some_and(|e| Arc::ptr_eq(&e.f, dx))
                    && self.map.get("cv::SobelY").is_some_and(|e| Arc::ptr_eq(&e.f, dy))
            }
            None => false,
        }
    }

    /// Attach a pooled form to an already-registered symbol.
    pub fn set_pooled(&mut self, symbol: &str, f: SwFnPooled) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.pooled = Some(f);
        }
    }

    /// Attach an in-place form to an already-registered symbol.
    pub fn set_inplace(&mut self, symbol: &str, f: SwFnInPlace) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.inplace = Some(f);
        }
    }

    /// Resolve a symbol (the `dlsym` analogue).
    pub fn resolve(&self, symbol: &str) -> Result<&FuncEntry> {
        self.map
            .get(symbol)
            .ok_or_else(|| CourierError::UnknownSymbol(symbol.to_string()))
    }

    /// True iff the symbol is linkable.
    pub fn contains(&self, symbol: &str) -> bool {
        self.map.contains_key(symbol)
    }

    /// All registered symbols, sorted.
    pub fn symbols(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    /// Invoke a symbol directly (resolve + arity check + call).
    pub fn call(&self, symbol: &str, args: &[&Mat]) -> Result<Mat> {
        let entry = self.resolve(symbol)?;
        if args.len() != entry.arity {
            return Err(CourierError::ShapeMismatch {
                context: symbol.to_string(),
                expected: format!("{} args", entry.arity),
                got: format!("{} args", args.len()),
            });
        }
        (entry.f)(args)
    }
}

/// Pooled form of a unary same-shape kernel with an `_into` variant.
fn pooled_unary(
    into: impl Fn(&Mat, &mut Mat) -> Result<()> + Send + Sync + 'static,
) -> SwFnPooled {
    Arc::new(move |a: &[&Mat], p: &BufferPool| {
        let mut out = p.acquire(a[0].shape());
        into(a[0], &mut out)?;
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn standard_has_the_case_study_functions() {
        let r = Registry::standard();
        for sym in ["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"] {
            assert!(r.contains(sym), "{sym} missing");
        }
        assert!(r.contains(FUSED_CVT_HARRIS));
    }

    #[test]
    fn resolve_unknown_fails() {
        let r = Registry::standard();
        assert!(matches!(
            r.resolve("cv::doesNotExist"),
            Err(CourierError::UnknownSymbol(_))
        ));
    }

    #[test]
    fn call_checks_arity() {
        let r = Registry::standard();
        let img = synth::noise_gray(4, 4, 0);
        let err = r.call("blas::sgemm", &[&img]);
        assert!(err.is_err());
    }

    #[test]
    fn call_dispatches() {
        let r = Registry::standard();
        let img = synth::noise_rgb(4, 4, 0);
        let gray = r.call("cv::cvtColor", &[&img]).unwrap();
        assert_eq!(gray.shape(), &[4, 4]);
    }

    #[test]
    fn register_replaces() {
        let mut r = Registry::standard();
        r.register("cv::cvtColor", 1, Arc::new(|_: &[&Mat]| Ok(Mat::full(&[1, 1], 9.0))));
        let out = r.call("cv::cvtColor", &[&Mat::zeros(&[2, 2])]).unwrap();
        assert_eq!(out.as_slice(), &[9.0]);
        // replacing drops the hot-path variants with the old entry
        assert!(r.resolve("cv::cvtColor").unwrap().pooled.is_none());
    }

    #[test]
    fn fused_entry_tracks_constituent_identity() {
        let mut r = Registry::standard();
        let fused = r.resolve(FUSED_CVT_HARRIS).unwrap().clone();
        let cvt = r.resolve("cv::cvtColor").unwrap().clone();
        let harris = r.resolve("cv::cornerHarris").unwrap().clone();
        assert!(fused.fuses_exactly(&[&cvt, &harris]));
        assert!(!fused.fuses_exactly(&[&harris, &cvt]), "order matters");
        assert!(!fused.fuses_exactly(&[&cvt]), "arity matters");
        // re-registering a constituent breaks the identity link
        r.register("cv::cvtColor", 1, Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0])));
        let cvt2 = r.resolve("cv::cvtColor").unwrap().clone();
        assert!(!fused.fuses_exactly(&[&cvt2, &harris]));
    }

    #[test]
    fn pooled_and_inplace_forms_match_plain_calls() {
        let r = Registry::standard();
        let pool = BufferPool::new();
        let rgb = synth::noise_rgb(9, 11, 3);
        let gray = r.call("cv::cvtColor", &[&rgb]).unwrap();
        for sym in [
            "cv::Sobel",
            "cv::SobelY",
            "cv::GaussianBlur",
            "cv::boxFilter",
            "cv::erode",
            "cv::dilate",
            "cv::Laplacian",
            "cv::Scharr",
            "cv::medianBlur",
            "cv::cornerHarris",
            "cv::normalize",
            "cv::convertScaleAbs",
            "cv::threshold",
        ] {
            let entry = r.resolve(sym).unwrap();
            let plain = (entry.f)(&[&gray]).unwrap();
            let pooled = entry.pooled.as_ref().expect(sym)(&[&gray], &pool).unwrap();
            assert_eq!(plain, pooled, "{sym} pooled form diverges");
            if let Some(ip) = &entry.inplace {
                assert_eq!(plain, ip(gray.clone()).unwrap(), "{sym} in-place form diverges");
            }
        }
        // the fused mega-kernel and the 2-ary response
        let entry = r.resolve(FUSED_CVT_HARRIS).unwrap();
        let plain = (entry.f)(&[&rgb]).unwrap();
        let pooled = entry.pooled.as_ref().unwrap()(&[&rgb], &pool).unwrap();
        assert_eq!(plain, pooled);
        let entry = r.resolve("cv::harrisResponse").unwrap();
        let plain = (entry.f)(&[&gray, &gray]).unwrap();
        let pooled = entry.pooled.as_ref().unwrap()(&[&gray, &gray], &pool).unwrap();
        assert_eq!(plain, pooled);
    }
}
