//! Symbol registry — the dynamic-linker substrate.
//!
//! The interpreter resolves every call through a [`Registry`]; the
//! Function Off-loader later *re-binds* symbols in a separate hook table
//! (see `offload::HookTable`), so the registry itself always answers with
//! the original library function — the paper's `dlsym(RTLD_NEXT, ...)`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::image::Mat;
use crate::{CourierError, Result};

use super::{blas, imgproc};

/// A library function: a boxed pure function over `Mat` arguments.
pub type SwFn = Arc<dyn Fn(&[&Mat]) -> Result<Mat> + Send + Sync>;

/// One resolvable library symbol.
#[derive(Clone)]
pub struct FuncEntry {
    /// Fully qualified symbol, e.g. `cv::cornerHarris`.
    pub symbol: String,
    /// Number of `Mat` arguments.
    pub arity: usize,
    /// The callable.
    pub f: SwFn,
}

impl std::fmt::Debug for FuncEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FuncEntry")
            .field("symbol", &self.symbol)
            .field("arity", &self.arity)
            .finish()
    }
}

/// The function library a target binary links against.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    map: BTreeMap<String, FuncEntry>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The standard library: every OpenCV/BLAS function the case-study
    /// binaries call, with the demo parameters baked in (blockSize=3,
    /// ksize=3, k=0.04 for Harris; alpha=1, beta=0 for convertScaleAbs;
    /// ... — identical to the AOT module catalog in `python/compile`).
    pub fn standard() -> Self {
        let mut r = Self::new();
        r.register("cv::cvtColor", 1, Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0])));
        r.register("cv::Sobel", 1, Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 1, 0)));
        r.register("cv::SobelY", 1, Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 0, 1)));
        r.register("cv::GaussianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::gaussian_blur(a[0])));
        r.register("cv::boxFilter", 1, Arc::new(|a: &[&Mat]| imgproc::box_filter(a[0], true)));
        r.register("cv::erode", 1, Arc::new(|a: &[&Mat]| imgproc::erode(a[0])));
        r.register("cv::dilate", 1, Arc::new(|a: &[&Mat]| imgproc::dilate(a[0])));
        r.register("cv::Laplacian", 1, Arc::new(|a: &[&Mat]| imgproc::laplacian(a[0])));
        r.register("cv::Scharr", 1, Arc::new(|a: &[&Mat]| imgproc::scharr(a[0])));
        r.register("cv::medianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::median_blur(a[0])));
        r.register(
            "cv::cornerHarris",
            1,
            Arc::new(|a: &[&Mat]| imgproc::corner_harris(a[0], imgproc::HARRIS_K)),
        );
        r.register(
            "cv::harrisResponse",
            2,
            Arc::new(|a: &[&Mat]| imgproc::harris_response(a[0], a[1], imgproc::HARRIS_K)),
        );
        r.register(
            "cv::normalize",
            1,
            Arc::new(|a: &[&Mat]| imgproc::normalize(a[0], 0.0, 255.0)),
        );
        r.register(
            "cv::convertScaleAbs",
            1,
            Arc::new(|a: &[&Mat]| imgproc::convert_scale_abs(a[0], 1.0, 0.0)),
        );
        r.register(
            "cv::threshold",
            1,
            Arc::new(|a: &[&Mat]| imgproc::threshold(a[0], 127.0, 255.0)),
        );
        r.register("blas::sgemm", 2, Arc::new(|a: &[&Mat]| blas::sgemm(a[0], a[1])));
        r.register("blas::saxpy", 2, Arc::new(|a: &[&Mat]| blas::saxpy(1.0, a[0], a[1])));
        r.register("blas::sdot", 2, Arc::new(|a: &[&Mat]| blas::sdot(a[0], a[1])));
        r
    }

    /// Register (or replace) a symbol.
    pub fn register(&mut self, symbol: &str, arity: usize, f: SwFn) {
        self.map.insert(
            symbol.to_string(),
            FuncEntry { symbol: symbol.to_string(), arity, f },
        );
    }

    /// Resolve a symbol (the `dlsym` analogue).
    pub fn resolve(&self, symbol: &str) -> Result<&FuncEntry> {
        self.map
            .get(symbol)
            .ok_or_else(|| CourierError::UnknownSymbol(symbol.to_string()))
    }

    /// True iff the symbol is linkable.
    pub fn contains(&self, symbol: &str) -> bool {
        self.map.contains_key(symbol)
    }

    /// All registered symbols, sorted.
    pub fn symbols(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    /// Invoke a symbol directly (resolve + arity check + call).
    pub fn call(&self, symbol: &str, args: &[&Mat]) -> Result<Mat> {
        let entry = self.resolve(symbol)?;
        if args.len() != entry.arity {
            return Err(CourierError::ShapeMismatch {
                context: symbol.to_string(),
                expected: format!("{} args", entry.arity),
                got: format!("{} args", args.len()),
            });
        }
        (entry.f)(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn standard_has_the_case_study_functions() {
        let r = Registry::standard();
        for sym in ["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"] {
            assert!(r.contains(sym), "{sym} missing");
        }
    }

    #[test]
    fn resolve_unknown_fails() {
        let r = Registry::standard();
        assert!(matches!(
            r.resolve("cv::doesNotExist"),
            Err(CourierError::UnknownSymbol(_))
        ));
    }

    #[test]
    fn call_checks_arity() {
        let r = Registry::standard();
        let img = synth::noise_gray(4, 4, 0);
        let err = r.call("blas::sgemm", &[&img]);
        assert!(err.is_err());
    }

    #[test]
    fn call_dispatches() {
        let r = Registry::standard();
        let img = synth::noise_rgb(4, 4, 0);
        let gray = r.call("cv::cvtColor", &[&img]).unwrap();
        assert_eq!(gray.shape(), &[4, 4]);
    }

    #[test]
    fn register_replaces() {
        let mut r = Registry::standard();
        r.register("cv::cvtColor", 1, Arc::new(|_: &[&Mat]| Ok(Mat::full(&[1, 1], 9.0))));
        let out = r.call("cv::cvtColor", &[&Mat::zeros(&[2, 2])]).unwrap();
        assert_eq!(out.as_slice(), &[9.0]);
    }
}
