//! Symbol registry — the dynamic-linker substrate.
//!
//! The interpreter resolves every call through a [`Registry`]; the
//! Function Off-loader later *re-binds* symbols in a separate hook table
//! (see `offload::HookTable`), so the registry itself always answers with
//! the original library function — the paper's `dlsym(RTLD_NEXT, ...)`.
//!
//! Besides the plain callable, an entry may carry two hot-path variants
//! the pipeline builder routes through when it can prove they are safe:
//!
//! * a **pooled** form (`Fn(&[&Mat], &BufferPool) -> Mat`) that draws its
//!   output and scratch from the pipeline's capacity-class buffer pool, and
//! * an **in-place** form (`Fn(Mat) -> Mat`) for unary elementwise ops,
//!   used when liveness says the input buffer dies at this call.
//!
//! Both must be numerically identical to the plain callable (the kernel
//! parity suite pins this); the interpreter and tracer always use the
//! plain form, so traces stay independent of pipeline execution details.
//!
//! The registry is also the substrate of the builder's **generalized
//! fusion planner**:
//!
//! * [`Registry::compose_chain`] turns any run of chained symbols into
//!   one composed entry whose pooled form threads intermediates through
//!   stack-scoped pool scratch (acquire → consume → release, or the
//!   constituent's in-place form) — a fused run allocates nothing in
//!   steady state.  A registered mega-kernel covering the exact run
//!   (e.g. [`FUSED_CVT_HARRIS`]) is preferred over generic composition.
//! * [`Registry::register_sibling_pair`] declares a one-walk two-output
//!   kernel for a matched pair of sibling stencils sharing one input
//!   (e.g. the Sobel dx/dy pair); the builder substitutes it for a
//!   two-branch fork-join stage.
//! * Both are gated on **per-link provenance**: [`Registry::mark_fusable`]
//!   records the exact callable a symbol resolved to when it was declared
//!   fusable, and [`Registry::link_intact`] checks pointer identity
//!   against the live entry.  Re-registering a constituent (the override
//!   pattern) silently disables just the links that touch it — the
//!   override always runs; fusion never bypasses it.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::image::Mat;
use crate::pipeline::BufferPool;
use crate::{CourierError, Result};

use super::{blas, imgproc};

/// A library function: a boxed pure function over `Mat` arguments.
pub type SwFn = Arc<dyn Fn(&[&Mat]) -> Result<Mat> + Send + Sync>;

/// Pool-aware variant: output (and any scratch) comes from the pool.
pub type SwFnPooled = Arc<dyn Fn(&[&Mat], &BufferPool) -> Result<Mat> + Send + Sync>;

/// In-place variant for unary elementwise ops: consumes the (dead) input
/// buffer and returns it transformed.
pub type SwFnInPlace = Arc<dyn Fn(Mat) -> Result<Mat> + Send + Sync>;

/// One-walk sibling-pair kernel: reads the shared input once and writes
/// both siblings' outputs (same shape as the input) in a single pass.
pub type SwFnPair = Arc<dyn Fn(&Mat, &mut Mat, &mut Mat) -> Result<()> + Send + Sync>;

/// Scalar-parameterized library function: `Mat` buffers plus per-frame
/// scalar constants (Courier-Script `const` values at the call site).
pub type SwFnScalar = Arc<dyn Fn(&[&Mat], &[f64]) -> Result<Mat> + Send + Sync>;

/// Pool-aware scalar form: output and scratch come from the pool.
pub type SwFnScalarPooled =
    Arc<dyn Fn(&[&Mat], &[f64], &BufferPool) -> Result<Mat> + Send + Sync>;

/// The fused gray→response mega-kernel the builder selects when
/// consecutive software tasks cover the whole `cvtColor → cornerHarris`
/// chain inside one stage (same naming convention as the AOT module
/// catalog's fused hardware entry).
pub const FUSED_CVT_HARRIS: &str = "cv::cvtColor+cv::cornerHarris";

/// Label of the fused one-walk Sobel dx+dy pair the builder selects when
/// a fork-join stage holds exactly the two sibling gradients over one
/// shared input ([`imgproc::sobel_xy_into`]).
pub const FUSED_SOBEL_PAIR: &str = "cv::Sobel+cv::SobelY";

/// Label of the fused one-walk erode+dilate pair the builder selects when
/// a fork-join stage holds exactly the two morphology siblings over one
/// shared input ([`imgproc::erode_dilate_into`]).
pub const FUSED_MORPH_PAIR: &str = "cv::erode+cv::dilate";

/// One resolvable library symbol.
#[derive(Clone)]
pub struct FuncEntry {
    /// Fully qualified symbol, e.g. `cv::cornerHarris`.
    pub symbol: String,
    /// Number of `Mat` arguments.
    pub arity: usize,
    /// The callable.
    pub f: SwFn,
    /// Optional pool-aware form (same numerics, pooled buffers).
    pub pooled: Option<SwFnPooled>,
    /// Optional in-place form (same numerics, reuses the input buffer).
    pub inplace: Option<SwFnInPlace>,
    /// For a fused mega-kernel: the exact callables it composes, in
    /// chain order.  The builder only selects the fused binding while
    /// the live registry still resolves the constituent symbols to these
    /// same `Arc`s — re-registering either constituent (the override
    /// pattern) silently disables fusion instead of bypassing the
    /// override.
    pub fused_of: Option<Vec<SwFn>>,
}

impl FuncEntry {
    /// True iff this entry is a fused kernel whose constituents are
    /// exactly `parts` (pointer identity on the callables).
    pub fn fuses_exactly(&self, parts: &[&FuncEntry]) -> bool {
        match &self.fused_of {
            Some(own) => {
                own.len() == parts.len()
                    && own.iter().zip(parts).all(|(a, b)| Arc::ptr_eq(a, &b.f))
            }
            None => false,
        }
    }
}

impl std::fmt::Debug for FuncEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FuncEntry")
            .field("symbol", &self.symbol)
            .field("arity", &self.arity)
            .field("pooled", &self.pooled.is_some())
            .field("inplace", &self.inplace.is_some())
            .finish()
    }
}

/// A scalar-parameterized resolvable symbol: the same library function
/// with its baked-in constants lifted into call-site scalars.  Scalar
/// entries live beside the plain table — a call with no scalars always
/// resolves to the plain [`FuncEntry`], so existing traces and plans are
/// untouched.
#[derive(Clone)]
pub struct ScalarEntry {
    /// Fully qualified symbol, e.g. `cv::cornerHarris`.
    pub symbol: String,
    /// Number of `Mat` arguments.
    pub arity: usize,
    /// Number of scalar arguments.
    pub nscalars: usize,
    /// The callable.
    pub f: SwFnScalar,
    /// Optional pool-aware form (same numerics, pooled buffers).
    pub pooled: Option<SwFnScalarPooled>,
}

impl std::fmt::Debug for ScalarEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScalarEntry")
            .field("symbol", &self.symbol)
            .field("arity", &self.arity)
            .field("nscalars", &self.nscalars)
            .finish()
    }
}

/// A registered one-walk sibling-pair kernel: `f` computes what the two
/// constituent unary kernels `(a, b)` would over one shared input, in a
/// single image walk writing both outputs.
#[derive(Clone)]
pub struct PairEntry {
    /// Display label, `"<a>+<b>"` (what the stage label shows).
    pub label: String,
    /// First constituent symbol (its output is the pair's first output).
    pub a: String,
    /// Second constituent symbol.
    pub b: String,
    /// The exact constituent callables recorded at registration — the
    /// provenance link [`Registry::sibling_pair`] checks before the
    /// builder may substitute the pair.
    parts: (SwFn, SwFn),
    /// The one-walk kernel.
    pub f: SwFnPair,
}

impl std::fmt::Debug for PairEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PairEntry").field("label", &self.label).finish()
    }
}

/// The function library a target binary links against.
#[derive(Clone, Default)]
pub struct Registry {
    map: BTreeMap<String, FuncEntry>,
    /// Per-symbol fusion-provenance anchors: the exact callable each
    /// symbol resolved to when it was declared chain-fusable
    /// ([`Registry::mark_fusable`]).  [`Registry::link_intact`] compares
    /// the live entry against this by pointer identity, so re-registering
    /// a symbol disables just the fusion links that touch it.
    fusable: BTreeMap<String, SwFn>,
    /// Registered one-walk sibling-pair kernels.
    pairs: Vec<PairEntry>,
    /// Scalar-parameterized forms, keyed by symbol.
    scalar_map: BTreeMap<String, ScalarEntry>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("symbols", &self.map.keys().collect::<Vec<_>>())
            .field("fusable", &self.fusable.keys().collect::<Vec<_>>())
            .field("pairs", &self.pairs.iter().map(|p| &p.label).collect::<Vec<_>>())
            .finish()
    }
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The standard library: every OpenCV/BLAS function the case-study
    /// binaries call, with the demo parameters baked in (blockSize=3,
    /// ksize=3, k=0.04 for Harris; alpha=1, beta=0 for convertScaleAbs;
    /// ... — identical to the AOT module catalog in `python/compile`).
    pub fn standard() -> Self {
        use imgproc::HARRIS_K;
        let mut r = Self::new();
        // the cvt/harris callables are bound to locals so the fused
        // mega-kernel can record exactly which implementations it fuses
        let cvt_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0]));
        let harris_f: SwFn = Arc::new(|a: &[&Mat]| imgproc::corner_harris(a[0], HARRIS_K));
        r.register("cv::cvtColor", 1, cvt_f.clone());
        r.register("cv::Sobel", 1, Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 1, 0)));
        r.register("cv::SobelY", 1, Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 0, 1)));
        r.register("cv::GaussianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::gaussian_blur(a[0])));
        r.register("cv::boxFilter", 1, Arc::new(|a: &[&Mat]| imgproc::box_filter(a[0], true)));
        r.register("cv::erode", 1, Arc::new(|a: &[&Mat]| imgproc::erode(a[0])));
        r.register("cv::dilate", 1, Arc::new(|a: &[&Mat]| imgproc::dilate(a[0])));
        r.register("cv::Laplacian", 1, Arc::new(|a: &[&Mat]| imgproc::laplacian(a[0])));
        r.register("cv::Scharr", 1, Arc::new(|a: &[&Mat]| imgproc::scharr(a[0])));
        r.register("cv::medianBlur", 1, Arc::new(|a: &[&Mat]| imgproc::median_blur(a[0])));
        r.register("cv::pyrDown", 1, Arc::new(|a: &[&Mat]| imgproc::pyr_down(a[0])));
        r.register("cv::cornerHarris", 1, harris_f.clone());
        r.register(
            "cv::harrisResponse",
            2,
            Arc::new(|a: &[&Mat]| imgproc::harris_response(a[0], a[1], HARRIS_K)),
        );
        r.register(
            "cv::normalize",
            1,
            Arc::new(|a: &[&Mat]| imgproc::normalize(a[0], 0.0, 255.0)),
        );
        r.register(
            "cv::convertScaleAbs",
            1,
            Arc::new(|a: &[&Mat]| imgproc::convert_scale_abs(a[0], 1.0, 0.0)),
        );
        r.register(
            "cv::threshold",
            1,
            Arc::new(|a: &[&Mat]| imgproc::threshold(a[0], 127.0, 255.0)),
        );
        r.register(
            FUSED_CVT_HARRIS,
            1,
            Arc::new(|a: &[&Mat]| imgproc::harris_pipeline(a[0], HARRIS_K)),
        );
        r.set_fused_of(FUSED_CVT_HARRIS, vec![cvt_f, harris_f]);
        r.register("blas::sgemm", 2, Arc::new(|a: &[&Mat]| blas::sgemm(a[0], a[1])));
        r.register("blas::saxpy", 2, Arc::new(|a: &[&Mat]| blas::saxpy(1.0, a[0], a[1])));
        r.register("blas::sdot", 2, Arc::new(|a: &[&Mat]| blas::sdot(a[0], a[1])));

        // ---- pooled forms (output + scratch from the buffer pool) -----
        r.set_pooled(
            "cv::cvtColor",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire(&[a[0].height(), a[0].width()]);
                imgproc::cvt_color_into(a[0], &mut out)?;
                Ok(out)
            }),
        );
        r.set_pooled("cv::Sobel", pooled_unary(|img, out| imgproc::sobel_into(img, 1, 0, out)));
        r.set_pooled("cv::SobelY", pooled_unary(|img, out| imgproc::sobel_into(img, 0, 1, out)));
        r.set_pooled(
            "cv::GaussianBlur",
            Arc::new(|a: &[&Mat], p: &BufferPool| imgproc::gaussian_blur_pooled(a[0], p)),
        );
        r.set_pooled("cv::boxFilter", pooled_unary(|img, out| imgproc::box_filter_into(img, true, out)));
        r.set_pooled("cv::erode", pooled_unary(imgproc::erode_into));
        r.set_pooled("cv::dilate", pooled_unary(imgproc::dilate_into));
        r.set_pooled("cv::Laplacian", pooled_unary(imgproc::laplacian_into));
        r.set_pooled("cv::Scharr", pooled_unary(imgproc::scharr_into));
        r.set_pooled("cv::medianBlur", pooled_unary(imgproc::median_blur_into));
        r.set_pooled(
            "cv::pyrDown",
            Arc::new(|a: &[&Mat], p: &BufferPool| imgproc::pyr_down_pooled(a[0], p)),
        );
        r.set_pooled(
            "cv::cornerHarris",
            Arc::new(|a: &[&Mat], p: &BufferPool| imgproc::corner_harris_pooled(a[0], HARRIS_K, p)),
        );
        r.set_pooled(
            "cv::harrisResponse",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                imgproc::harris_response_pooled(a[0], a[1], HARRIS_K, p)
            }),
        );
        r.set_pooled(
            FUSED_CVT_HARRIS,
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                imgproc::harris_pipeline_pooled(a[0], HARRIS_K, p)
            }),
        );
        r.set_pooled(
            "cv::normalize",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::normalize_mut(&mut out, 0.0, 255.0)?;
                Ok(out)
            }),
        );
        r.set_pooled(
            "cv::convertScaleAbs",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::convert_scale_abs_mut(&mut out, 1.0, 0.0)?;
                Ok(out)
            }),
        );
        r.set_pooled(
            "cv::threshold",
            Arc::new(|a: &[&Mat], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::threshold_mut(&mut out, 127.0, 255.0)?;
                Ok(out)
            }),
        );

        // ---- in-place forms (input buffer dies at the call) -----------
        r.set_inplace(
            "cv::normalize",
            Arc::new(|mut m: Mat| {
                imgproc::normalize_mut(&mut m, 0.0, 255.0)?;
                Ok(m)
            }),
        );
        r.set_inplace(
            "cv::convertScaleAbs",
            Arc::new(|mut m: Mat| {
                imgproc::convert_scale_abs_mut(&mut m, 1.0, 0.0)?;
                Ok(m)
            }),
        );
        r.set_inplace(
            "cv::threshold",
            Arc::new(|mut m: Mat| {
                imgproc::threshold_mut(&mut m, 127.0, 255.0)?;
                Ok(m)
            }),
        );

        // ---- scalar-parameterized forms (Courier-Script `const`) ------
        // each is the same kernel as the plain entry with its baked-in
        // constant lifted to a call-site scalar; the parity suite pins
        // scalar(defaults) == plain
        r.register_scalar(
            "cv::cornerHarris",
            1,
            1,
            Arc::new(|a: &[&Mat], s: &[f64]| imgproc::corner_harris(a[0], s[0] as f32)),
        );
        r.set_scalar_pooled(
            "cv::cornerHarris",
            Arc::new(|a: &[&Mat], s: &[f64], p: &BufferPool| {
                imgproc::corner_harris_pooled(a[0], s[0] as f32, p)
            }),
        );
        r.register_scalar(
            "cv::harrisResponse",
            2,
            1,
            Arc::new(|a: &[&Mat], s: &[f64]| imgproc::harris_response(a[0], a[1], s[0] as f32)),
        );
        r.set_scalar_pooled(
            "cv::harrisResponse",
            Arc::new(|a: &[&Mat], s: &[f64], p: &BufferPool| {
                imgproc::harris_response_pooled(a[0], a[1], s[0] as f32, p)
            }),
        );
        r.register_scalar(
            "cv::threshold",
            1,
            2,
            Arc::new(|a: &[&Mat], s: &[f64]| imgproc::threshold(a[0], s[0] as f32, s[1] as f32)),
        );
        r.set_scalar_pooled(
            "cv::threshold",
            Arc::new(|a: &[&Mat], s: &[f64], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::threshold_mut(&mut out, s[0] as f32, s[1] as f32)?;
                Ok(out)
            }),
        );
        r.register_scalar(
            "cv::normalize",
            1,
            2,
            Arc::new(|a: &[&Mat], s: &[f64]| imgproc::normalize(a[0], s[0] as f32, s[1] as f32)),
        );
        r.set_scalar_pooled(
            "cv::normalize",
            Arc::new(|a: &[&Mat], s: &[f64], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::normalize_mut(&mut out, s[0] as f32, s[1] as f32)?;
                Ok(out)
            }),
        );
        r.register_scalar(
            "cv::convertScaleAbs",
            1,
            2,
            Arc::new(|a: &[&Mat], s: &[f64]| {
                imgproc::convert_scale_abs(a[0], s[0] as f32, s[1] as f32)
            }),
        );
        r.set_scalar_pooled(
            "cv::convertScaleAbs",
            Arc::new(|a: &[&Mat], s: &[f64], p: &BufferPool| {
                let mut out = p.acquire_cloned(a[0]);
                imgproc::convert_scale_abs_mut(&mut out, s[0] as f32, s[1] as f32)?;
                Ok(out)
            }),
        );
        r.register_scalar(
            "blas::saxpy",
            2,
            1,
            Arc::new(|a: &[&Mat], s: &[f64]| blas::saxpy(s[0] as f32, a[0], a[1])),
        );

        // ---- fusion substrate -----------------------------------------
        // the one-walk Sobel dx+dy pair for fork-join sibling stages
        r.register_sibling_pair(
            "cv::Sobel",
            "cv::SobelY",
            Arc::new(|src: &Mat, dx: &mut Mat, dy: &mut Mat| imgproc::sobel_xy_into(src, dx, dy)),
        )
        .expect("standard Sobel kernels are registered above");
        // the one-walk erode+dilate pair (morphological-gradient forks)
        r.register_sibling_pair(
            "cv::erode",
            "cv::dilate",
            Arc::new(|src: &Mat, er: &mut Mat, di: &mut Mat| {
                imgproc::erode_dilate_into(src, er, di)
            }),
        )
        .expect("standard morphology kernels are registered above");
        // every standard kernel is chain-fusable while it still resolves
        // to the implementation recorded here (per-link provenance)
        for sym in [
            "cv::cvtColor",
            "cv::Sobel",
            "cv::SobelY",
            "cv::GaussianBlur",
            "cv::boxFilter",
            "cv::erode",
            "cv::dilate",
            "cv::Laplacian",
            "cv::Scharr",
            "cv::medianBlur",
            "cv::pyrDown",
            "cv::cornerHarris",
            "cv::harrisResponse",
            "cv::normalize",
            "cv::convertScaleAbs",
            "cv::threshold",
        ] {
            let anchored = r.mark_fusable(sym);
            debug_assert!(anchored, "standard symbol {sym} must be registered before anchoring");
        }
        r
    }

    /// Register (or replace) a symbol.
    pub fn register(&mut self, symbol: &str, arity: usize, f: SwFn) {
        self.map.insert(
            symbol.to_string(),
            FuncEntry {
                symbol: symbol.to_string(),
                arity,
                f,
                pooled: None,
                inplace: None,
                fused_of: None,
            },
        );
    }

    /// Declare an already-registered symbol as a fused kernel composing
    /// exactly `parts` (in chain order).
    pub fn set_fused_of(&mut self, symbol: &str, parts: Vec<SwFn>) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.fused_of = Some(parts);
        }
    }

    /// Declare a symbol chain-fusable, anchoring its *current* callable
    /// as the provenance the fusion planner checks.  Re-registering the
    /// symbol afterwards breaks the anchor ([`Registry::link_intact`])
    /// and thereby disables exactly the fusion links that touch it.
    /// Returns `false` (and anchors nothing) if the symbol is not
    /// registered — callers wiring up custom kernels should check it
    /// rather than discover later that fusion silently never fires.
    pub fn mark_fusable(&mut self, symbol: &str) -> bool {
        match self.map.get(symbol) {
            Some(e) => {
                let f = e.f.clone();
                self.fusable.insert(symbol.to_string(), f);
                true
            }
            None => false,
        }
    }

    /// True while `symbol` still resolves to the exact callable recorded
    /// by [`Registry::mark_fusable`] — the per-link gate of the fusion
    /// planner.  Symbols never marked fusable are never fused.
    pub fn link_intact(&self, symbol: &str) -> bool {
        match self.fusable.get(symbol) {
            Some(anchor) => self
                .map
                .get(symbol)
                .is_some_and(|e| Arc::ptr_eq(&e.f, anchor)),
            None => false,
        }
    }

    /// Register a one-walk sibling-pair kernel for unary symbols `a` and
    /// `b` over one shared input.  `f` must write, in a single pass, what
    /// `a` produces into its first output and what `b` produces into its
    /// second (both input-shaped) — bit-for-bit.  The pair records the
    /// constituents' current callables as provenance; an unregistered
    /// constituent is a typed error, not a silent no-op.
    pub fn register_sibling_pair(&mut self, a: &str, b: &str, f: SwFnPair) -> Result<()> {
        let parts = (self.resolve(a)?.f.clone(), self.resolve(b)?.f.clone());
        self.pairs.push(PairEntry {
            label: format!("{a}+{b}"),
            a: a.to_string(),
            b: b.to_string(),
            parts,
            f,
        });
        Ok(())
    }

    /// The registered sibling pair for `(a, b)` — in that order — while
    /// both constituents still resolve to the callables recorded at
    /// registration.  Re-registering either symbol disables the pair
    /// instead of bypassing the override.
    pub fn sibling_pair(&self, a: &str, b: &str) -> Option<&PairEntry> {
        self.pairs.iter().find(|p| {
            p.a == a
                && p.b == b
                && self.map.get(a).is_some_and(|e| Arc::ptr_eq(&e.f, &p.parts.0))
                && self.map.get(b).is_some_and(|e| Arc::ptr_eq(&e.f, &p.parts.1))
        })
    }

    /// True while the one-walk Sobel dx/dy pair ([`FUSED_SOBEL_PAIR`]) is
    /// still substitutable — kept as a convenience over
    /// [`Registry::sibling_pair`] for the standard pair.
    pub fn sobel_pair_intact(&self) -> bool {
        self.sibling_pair("cv::Sobel", "cv::SobelY").is_some()
    }

    /// Compose a run of chained symbols into one bound entry: the first
    /// constituent consumes the run's external arguments, every later one
    /// consumes its predecessor's output (so all but the first must be
    /// unary).  A registered mega-kernel under the canonical joined name
    /// (`"a+b+..."`) whose [`FuncEntry::fuses_exactly`] matches the live
    /// constituents is preferred; otherwise a generic composition is
    /// built whose pooled form threads every intermediate through
    /// stack-scoped pool scratch (the constituent's in-place form when it
    /// has one, else pooled-acquire → release) — a fused run touches the
    /// frame environment only at its two ends and allocates nothing in
    /// steady state.  The caller is responsible for provenance gating
    /// ([`Registry::link_intact`]) and dataflow legality.
    pub fn compose_chain(&self, symbols: &[&str]) -> Result<FuncEntry> {
        if symbols.len() < 2 {
            return Err(CourierError::Other(
                "compose_chain needs at least two symbols".into(),
            ));
        }
        let parts: Vec<FuncEntry> = symbols
            .iter()
            .map(|s| self.resolve(s).cloned())
            .collect::<Result<_>>()?;
        for p in &parts[1..] {
            if p.arity != 1 {
                return Err(CourierError::Other(format!(
                    "compose_chain: interior constituent {} has arity {} (must be 1)",
                    p.symbol, p.arity
                )));
            }
        }
        let joined = symbols.join("+");
        // a hand-tuned mega-kernel covering exactly this run wins
        if let Some(e) = self.map.get(&joined) {
            if e.fuses_exactly(&parts.iter().collect::<Vec<_>>()) {
                return Ok(e.clone());
            }
        }
        let arity = parts[0].arity;
        let fused_of: Vec<SwFn> = parts.iter().map(|p| p.f.clone()).collect();
        // a fully elementwise run composes an in-place form too, so the
        // builder's dying-input fast path stays zero-copy through fusion
        let inplace: Option<SwFnInPlace> = if arity == 1
            && parts.iter().all(|p| p.inplace.is_some())
        {
            let ips: Vec<SwFnInPlace> =
                parts.iter().map(|p| p.inplace.clone().expect("checked")).collect();
            Some(Arc::new(move |m: Mat| {
                let mut cur = m;
                for ip in &ips {
                    cur = ip(cur)?;
                }
                Ok(cur)
            }))
        } else {
            None
        };
        let plain_parts = parts.clone();
        let plain: SwFn = Arc::new(move |args: &[&Mat]| {
            let mut cur = (plain_parts[0].f)(args)?;
            for p in &plain_parts[1..] {
                cur = (p.f)(&[&cur])?;
            }
            Ok(cur)
        });
        let pooled_parts = parts;
        let pooled: SwFnPooled = Arc::new(move |args: &[&Mat], pool: &BufferPool| {
            let mut cur = match &pooled_parts[0].pooled {
                Some(pf) => pf(args, pool)?,
                None => (pooled_parts[0].f)(args)?,
            };
            for p in &pooled_parts[1..] {
                cur = if let Some(ip) = &p.inplace {
                    ip(cur)?
                } else {
                    let out = match &p.pooled {
                        Some(pf) => pf(&[&cur], pool)?,
                        None => (p.f)(&[&cur])?,
                    };
                    pool.release(cur);
                    out
                };
            }
            Ok(cur)
        });
        Ok(FuncEntry {
            symbol: joined,
            arity,
            f: plain,
            pooled: Some(pooled),
            inplace,
            fused_of: Some(fused_of),
        })
    }

    /// Attach a pooled form to an already-registered symbol.
    pub fn set_pooled(&mut self, symbol: &str, f: SwFnPooled) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.pooled = Some(f);
        }
    }

    /// Attach an in-place form to an already-registered symbol.
    pub fn set_inplace(&mut self, symbol: &str, f: SwFnInPlace) {
        if let Some(e) = self.map.get_mut(symbol) {
            e.inplace = Some(f);
        }
    }

    /// Register (or replace) a scalar-parameterized form of a symbol.
    pub fn register_scalar(&mut self, symbol: &str, arity: usize, nscalars: usize, f: SwFnScalar) {
        self.scalar_map.insert(
            symbol.to_string(),
            ScalarEntry { symbol: symbol.to_string(), arity, nscalars, f, pooled: None },
        );
    }

    /// Attach a pooled form to an already-registered scalar symbol.
    pub fn set_scalar_pooled(&mut self, symbol: &str, f: SwFnScalarPooled) {
        if let Some(e) = self.scalar_map.get_mut(symbol) {
            e.pooled = Some(f);
        }
    }

    /// Resolve the scalar-parameterized form of a symbol.
    pub fn resolve_scalar(&self, symbol: &str) -> Result<&ScalarEntry> {
        self.scalar_map.get(symbol).ok_or_else(|| {
            CourierError::UnknownSymbol(format!("{symbol} (scalar-parameterized form)"))
        })
    }

    /// True iff the symbol has a scalar-parameterized form.
    pub fn contains_scalar(&self, symbol: &str) -> bool {
        self.scalar_map.contains_key(symbol)
    }

    /// Invoke a scalar-parameterized symbol (resolve + arity checks + call).
    pub fn call_scalar(&self, symbol: &str, args: &[&Mat], scalars: &[f64]) -> Result<Mat> {
        let entry = self.resolve_scalar(symbol)?;
        if args.len() != entry.arity || scalars.len() != entry.nscalars {
            return Err(CourierError::ShapeMismatch {
                context: format!("{symbol} (scalar form)"),
                expected: format!("{} args + {} scalars", entry.arity, entry.nscalars),
                got: format!("{} args + {} scalars", args.len(), scalars.len()),
            });
        }
        (entry.f)(args, scalars)
    }

    /// Resolve a symbol (the `dlsym` analogue).
    pub fn resolve(&self, symbol: &str) -> Result<&FuncEntry> {
        self.map
            .get(symbol)
            .ok_or_else(|| CourierError::UnknownSymbol(symbol.to_string()))
    }

    /// True iff the symbol is linkable.
    pub fn contains(&self, symbol: &str) -> bool {
        self.map.contains_key(symbol)
    }

    /// All registered symbols, sorted.
    pub fn symbols(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    /// Invoke a symbol directly (resolve + arity check + call).
    pub fn call(&self, symbol: &str, args: &[&Mat]) -> Result<Mat> {
        let entry = self.resolve(symbol)?;
        if args.len() != entry.arity {
            return Err(CourierError::ShapeMismatch {
                context: symbol.to_string(),
                expected: format!("{} args", entry.arity),
                got: format!("{} args", args.len()),
            });
        }
        (entry.f)(args)
    }
}

/// Pooled form of a unary same-shape kernel with an `_into` variant.
fn pooled_unary(
    into: impl Fn(&Mat, &mut Mat) -> Result<()> + Send + Sync + 'static,
) -> SwFnPooled {
    Arc::new(move |a: &[&Mat], p: &BufferPool| {
        let mut out = p.acquire(a[0].shape());
        into(a[0], &mut out)?;
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn standard_has_the_case_study_functions() {
        let r = Registry::standard();
        for sym in ["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"] {
            assert!(r.contains(sym), "{sym} missing");
        }
        assert!(r.contains(FUSED_CVT_HARRIS));
    }

    #[test]
    fn resolve_unknown_fails() {
        let r = Registry::standard();
        assert!(matches!(
            r.resolve("cv::doesNotExist"),
            Err(CourierError::UnknownSymbol(_))
        ));
    }

    #[test]
    fn call_checks_arity() {
        let r = Registry::standard();
        let img = synth::noise_gray(4, 4, 0);
        let err = r.call("blas::sgemm", &[&img]);
        assert!(err.is_err());
    }

    #[test]
    fn call_dispatches() {
        let r = Registry::standard();
        let img = synth::noise_rgb(4, 4, 0);
        let gray = r.call("cv::cvtColor", &[&img]).unwrap();
        assert_eq!(gray.shape(), &[4, 4]);
    }

    #[test]
    fn register_replaces() {
        let mut r = Registry::standard();
        r.register("cv::cvtColor", 1, Arc::new(|_: &[&Mat]| Ok(Mat::full(&[1, 1], 9.0))));
        let out = r.call("cv::cvtColor", &[&Mat::zeros(&[2, 2])]).unwrap();
        assert_eq!(out.as_slice(), &[9.0]);
        // replacing drops the hot-path variants with the old entry
        assert!(r.resolve("cv::cvtColor").unwrap().pooled.is_none());
    }

    #[test]
    fn fused_entry_tracks_constituent_identity() {
        let mut r = Registry::standard();
        let fused = r.resolve(FUSED_CVT_HARRIS).unwrap().clone();
        let cvt = r.resolve("cv::cvtColor").unwrap().clone();
        let harris = r.resolve("cv::cornerHarris").unwrap().clone();
        assert!(fused.fuses_exactly(&[&cvt, &harris]));
        assert!(!fused.fuses_exactly(&[&harris, &cvt]), "order matters");
        assert!(!fused.fuses_exactly(&[&cvt]), "arity matters");
        // re-registering a constituent breaks the identity link
        r.register("cv::cvtColor", 1, Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0])));
        let cvt2 = r.resolve("cv::cvtColor").unwrap().clone();
        assert!(!fused.fuses_exactly(&[&cvt2, &harris]));
    }

    #[test]
    fn link_intact_tracks_reregistration() {
        let mut r = Registry::standard();
        assert!(r.link_intact("cv::cvtColor"));
        assert!(r.link_intact("cv::normalize"));
        assert!(!r.link_intact("blas::sgemm"), "never marked fusable");
        r.register("cv::cvtColor", 1, Arc::new(|a: &[&Mat]| imgproc::cvt_color(a[0])));
        assert!(!r.link_intact("cv::cvtColor"), "override must break the anchor");
        assert!(r.link_intact("cv::cornerHarris"), "other links stay intact");
        // re-marking re-anchors the new implementation
        r.mark_fusable("cv::cvtColor");
        assert!(r.link_intact("cv::cvtColor"));
    }

    #[test]
    fn sibling_pair_gated_on_provenance() {
        let mut r = Registry::standard();
        assert!(r.sibling_pair("cv::Sobel", "cv::SobelY").is_some());
        assert!(r.sibling_pair("cv::SobelY", "cv::Sobel").is_none(), "order matters");
        let morph = r.sibling_pair("cv::erode", "cv::dilate").expect("standard morph pair");
        assert_eq!(morph.label, FUSED_MORPH_PAIR);
        assert!(r.sobel_pair_intact());
        // an unregistered constituent is a typed error, not a silent no-op
        let err = r.register_sibling_pair(
            "cv::doesNotExist",
            "cv::Sobel",
            Arc::new(|_: &Mat, _: &mut Mat, _: &mut Mat| Ok(())),
        );
        assert!(matches!(err, Err(CourierError::UnknownSymbol(_))));
        assert!(!r.mark_fusable("cv::doesNotExist"));
        r.register("cv::SobelY", 1, Arc::new(|a: &[&Mat]| imgproc::sobel(a[0], 0, 1)));
        assert!(r.sibling_pair("cv::Sobel", "cv::SobelY").is_none());
        assert!(!r.sobel_pair_intact());
    }

    #[test]
    fn compose_chain_prefers_registered_mega_kernel() {
        let r = Registry::standard();
        let e = r.compose_chain(&["cv::cvtColor", "cv::cornerHarris"]).unwrap();
        assert_eq!(e.symbol, FUSED_CVT_HARRIS);
        // the mega-kernel, not a generic composition: same Arc as registered
        let reg = r.resolve(FUSED_CVT_HARRIS).unwrap();
        assert!(Arc::ptr_eq(&e.f, &reg.f));
    }

    #[test]
    fn compose_chain_generic_matches_back_to_back() {
        let r = Registry::standard();
        let pool = BufferPool::new();
        let gray = {
            let rgb = synth::noise_rgb(7, 9, 5);
            r.call("cv::cvtColor", &[&rgb]).unwrap()
        };
        let e = r
            .compose_chain(&["cv::GaussianBlur", "cv::normalize", "cv::threshold"])
            .unwrap();
        assert_eq!(e.arity, 1);
        assert_eq!(e.symbol, "cv::GaussianBlur+cv::normalize+cv::threshold");
        let want = {
            let a = r.call("cv::GaussianBlur", &[&gray]).unwrap();
            let b = r.call("cv::normalize", &[&a]).unwrap();
            r.call("cv::threshold", &[&b]).unwrap()
        };
        assert_eq!((e.f)(&[&gray]).unwrap(), want, "plain composition diverges");
        let pooled = e.pooled.as_ref().unwrap()(&[&gray], &pool).unwrap();
        assert_eq!(pooled, want, "pooled composition diverges");
        pool.release(pooled);
        // intermediates were recycled, not leaked: further pooled runs
        // allocate nothing new
        let warm = pool.stats().misses;
        for _ in 0..3 {
            let again = e.pooled.as_ref().unwrap()(&[&gray], &pool).unwrap();
            pool.release(again);
        }
        assert_eq!(pool.stats().misses, warm, "fused run must reuse pool scratch");
    }

    #[test]
    fn compose_chain_rejects_non_unary_interior() {
        let r = Registry::standard();
        let err = r.compose_chain(&["cv::cvtColor", "blas::sgemm"]).unwrap_err();
        assert!(err.to_string().contains("arity"));
        assert!(r.compose_chain(&["cv::cvtColor"]).is_err());
    }

    #[test]
    fn scalar_forms_match_plain_at_defaults() {
        // scalar(default constants) must be bit-identical to the plain
        // entry with those constants baked in
        let r = Registry::standard();
        let pool = BufferPool::new();
        let rgb = synth::noise_rgb(9, 11, 3);
        let gray = r.call("cv::cvtColor", &[&rgb]).unwrap();
        for (sym, scalars) in [
            ("cv::cornerHarris", vec![0.04]),
            ("cv::threshold", vec![127.0, 255.0]),
            ("cv::normalize", vec![0.0, 255.0]),
            ("cv::convertScaleAbs", vec![1.0, 0.0]),
        ] {
            let plain = r.call(sym, &[&gray]).unwrap();
            let scalar = r.call_scalar(sym, &[&gray], &scalars).unwrap();
            assert_eq!(plain, scalar, "{sym} scalar form diverges at defaults");
            let entry = r.resolve_scalar(sym).unwrap();
            if let Some(pf) = &entry.pooled {
                let pooled = pf(&[&gray], &scalars, &pool).unwrap();
                assert_eq!(plain, pooled, "{sym} pooled scalar form diverges");
                pool.release(pooled);
            }
        }
        // non-default constants actually change the result
        let hot = r.call_scalar("cv::threshold", &[&gray], &[10.0, 1.0]).unwrap();
        let cold = r.call("cv::threshold", &[&gray]).unwrap();
        assert_ne!(hot, cold);
        // arity mismatches are typed
        assert!(r.call_scalar("cv::threshold", &[&gray], &[1.0]).is_err());
        assert!(matches!(
            r.call_scalar("cv::erode", &[&gray], &[1.0]),
            Err(CourierError::UnknownSymbol(_))
        ));
    }

    #[test]
    fn pooled_and_inplace_forms_match_plain_calls() {
        let r = Registry::standard();
        let pool = BufferPool::new();
        let rgb = synth::noise_rgb(9, 11, 3);
        let gray = r.call("cv::cvtColor", &[&rgb]).unwrap();
        for sym in [
            "cv::Sobel",
            "cv::SobelY",
            "cv::GaussianBlur",
            "cv::boxFilter",
            "cv::erode",
            "cv::dilate",
            "cv::Laplacian",
            "cv::Scharr",
            "cv::medianBlur",
            "cv::pyrDown",
            "cv::cornerHarris",
            "cv::normalize",
            "cv::convertScaleAbs",
            "cv::threshold",
        ] {
            let entry = r.resolve(sym).unwrap();
            let plain = (entry.f)(&[&gray]).unwrap();
            let pooled = entry.pooled.as_ref().expect(sym)(&[&gray], &pool).unwrap();
            assert_eq!(plain, pooled, "{sym} pooled form diverges");
            if let Some(ip) = &entry.inplace {
                assert_eq!(plain, ip(gray.clone()).unwrap(), "{sym} in-place form diverges");
            }
        }
        // the fused mega-kernel and the 2-ary response
        let entry = r.resolve(FUSED_CVT_HARRIS).unwrap();
        let plain = (entry.f)(&[&rgb]).unwrap();
        let pooled = entry.pooled.as_ref().unwrap()(&[&rgb], &pool).unwrap();
        assert_eq!(plain, pooled);
        let entry = r.resolve("cv::harrisResponse").unwrap();
        let plain = (entry.f)(&[&gray, &gray]).unwrap();
        let pooled = entry.pooled.as_ref().unwrap()(&[&gray, &gray], &pool).unwrap();
        assert_eq!(plain, pooled);
    }
}
