//! Portable 8-lane `f32` vector for the stencil interiors.
//!
//! A deliberate stand-in for `std::simd::f32x8` (portable-SIMD is still
//! nightly-only): a `[f32; 8]` wrapper whose lanewise operators preserve
//! Rust's left-associative evaluation order **per lane**, so a vectorized
//! interior produces bit-identical results to the unrolled scalar loop it
//! replaces — the parity contract `tests/kernel_parity.rs` pins.  The
//! fixed-count lane loops are exactly the shape LLVM's SLP vectorizer
//! turns into one AVX/NEON op at `opt-level=3`; no intrinsics, no target
//! features, no unsafe.
//!
//! Whether kernels take this path is a *runtime* choice
//! ([`super::banding::simd_enabled`]), defaulting from the `simd` cargo
//! feature when declared — both paths always compile, so one test binary
//! covers both.

use std::ops::{Add, Mul, Neg, Sub};

/// Lane count of [`F32x8`].
pub const LANES: usize = 8;

/// Eight `f32` lanes with elementwise arithmetic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        Self([v; LANES])
    }

    /// Unaligned load of the first 8 elements of `s` (panics when short).
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        let mut lanes = [0.0f32; LANES];
        lanes.copy_from_slice(&s[..LANES]);
        Self(lanes)
    }

    /// Unaligned store into the first 8 elements of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..LANES].copy_from_slice(&self.0);
    }

    /// Lanewise minimum (same NaN semantics as `f32::min`).
    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = self.0[i].min(rhs.0[i]);
        }
        Self(lanes)
    }

    /// Lanewise maximum.
    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = self.0[i].max(rhs.0[i]);
        }
        Self(lanes)
    }
}

impl Add for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = self.0[i] + rhs.0[i];
        }
        Self(lanes)
    }
}

impl Sub for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = self.0[i] - rhs.0[i];
        }
        Self(lanes)
    }
}

impl Mul for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = self.0[i] * rhs.0[i];
        }
        Self(lanes)
    }
}

impl Neg for F32x8 {
    type Output = Self;
    /// Lanewise negation — true IEEE sign flip, **not** `0.0 - x` (which
    /// turns `-0.0` into `+0.0` and would break bitwise parity with the
    /// scalar `-a + c` stencil expressions).
    #[inline(always)]
    fn neg(self) -> Self {
        let mut lanes = [0.0f32; LANES];
        for i in 0..LANES {
            lanes[i] = -self.0[i];
        }
        Self(lanes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanewise_ops_match_scalar_bitwise() {
        let a: Vec<f32> = (0..LANES).map(|i| 0.3 + i as f32 * 1.7).collect();
        let b: Vec<f32> = (0..LANES).map(|i| -2.1 + i as f32 * 0.9).collect();
        let va = F32x8::load(&a);
        let vb = F32x8::load(&b);
        // the exact expression shape the stencil interiors use
        let v = F32x8::splat(0.25) * va + F32x8::splat(0.5) * vb - va * vb;
        for i in 0..LANES {
            let s = 0.25 * a[i] + 0.5 * b[i] - a[i] * b[i];
            assert_eq!(v.0[i].to_bits(), s.to_bits(), "lane {i}");
        }
        assert_eq!(va.min(vb).0[3], a[3].min(b[3]));
        assert_eq!(va.max(vb).0[3], a[3].max(b[3]));
        assert_eq!((-va).0[2].to_bits(), (-a[2]).to_bits());
        // sign flip keeps the signed zero the scalar path produces
        assert_eq!((-F32x8::splat(0.0)).0[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn load_store_roundtrip() {
        let src: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let v = F32x8::load(&src[2..]);
        let mut dst = vec![0.0f32; 10];
        v.store(&mut dst[1..]);
        assert_eq!(&dst[1..9], &src[2..10]);
        assert_eq!(F32x8::splat(3.5).0, [3.5; LANES]);
    }
}
