//! Raw trace records.

use crate::image::{sampled_hash, Mat};
use crate::util::json::{self, Json};
use crate::Result;

/// Shape + size + content hash of one buffer as observed at a call site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DataDesc {
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Payload bytes (f32).
    pub bytes: usize,
    /// FNV-1a content fingerprint — the causality key.
    pub hash: u64,
}

impl DataDesc {
    /// Describe a tensor.
    pub fn of(m: &Mat) -> Self {
        Self {
            shape: m.shape().to_vec(),
            bytes: m.byte_len(),
            hash: sampled_hash(m),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shape", Json::from_usizes(&self.shape)),
            ("bytes", Json::Num(self.bytes as f64)),
            // u64 hashes exceed f64's exact range: store as hex string
            ("hash", Json::Str(format!("{:016x}", self.hash))),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            shape: v.req("shape")?.as_usize_vec()?,
            bytes: v.req("bytes")?.as_usize()?,
            hash: u64::from_str_radix(v.req("hash")?.as_str()?, 16)
                .map_err(|e| crate::CourierError::Json(format!("bad hash: {e}")))?,
        })
    }
}

/// One observed library call.
#[derive(Debug, Clone, PartialEq)]
pub struct CallEvent {
    /// Global sequence number (chronological).
    pub seq: usize,
    /// Call-site step index within the binary.
    pub step: usize,
    /// Library symbol.
    pub symbol: String,
    /// Per-frame scalar constants observed at the call site (empty for
    /// plain buffer-only calls).
    pub scalars: Vec<f64>,
    /// Start timestamp, ns since tracer epoch.
    pub start_ns: u64,
    /// End timestamp, ns since tracer epoch.
    pub end_ns: u64,
    /// Input buffer descriptors.
    pub inputs: Vec<DataDesc>,
    /// Output buffer descriptor.
    pub output: DataDesc,
}

// Scalars are parsed literals, never NaN in practice.
impl Eq for CallEvent {}

impl CallEvent {
    /// Wall-clock duration of the call in ns.
    pub fn duration_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", Json::Num(self.seq as f64)),
            ("step", Json::Num(self.step as f64)),
            ("symbol", Json::Str(self.symbol.clone())),
        ];
        // omit-when-empty keeps pre-Courier-Script traces byte-identical
        if !self.scalars.is_empty() {
            fields.push((
                "scalars",
                Json::Arr(self.scalars.iter().map(|s| Json::Num(*s)).collect()),
            ));
        }
        fields.extend([
            ("start_ns", Json::Num(self.start_ns as f64)),
            ("end_ns", Json::Num(self.end_ns as f64)),
            ("inputs", Json::Arr(self.inputs.iter().map(DataDesc::to_json).collect())),
            ("output", self.output.to_json()),
        ]);
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let scalars = match v.get("scalars") {
            Some(arr) => arr.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>()?,
            None => Vec::new(),
        };
        Ok(Self {
            seq: v.req("seq")?.as_usize()?,
            step: v.req("step")?.as_usize()?,
            symbol: v.req("symbol")?.as_str()?.to_string(),
            scalars,
            start_ns: v.req("start_ns")?.as_u64()?,
            end_ns: v.req("end_ns")?.as_u64()?,
            inputs: v
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(DataDesc::from_json)
                .collect::<Result<_>>()?,
            output: DataDesc::from_json(v.req("output")?)?,
        })
    }
}

/// A full recording: the Frontend's Step-2 output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    /// Name of the traced binary.
    pub program: String,
    /// Chronological events (possibly spanning several frames).
    pub events: Vec<CallEvent>,
}

impl Trace {
    /// Number of frames observed, inferred from call-site repetition: the
    /// most-repeated step index bounds the frame count from below and is
    /// exact for every attach point.  (Counting only the smallest step
    /// undercounts when the tracer attaches mid-frame: the partial first
    /// frame never reaches the early steps, but its tail steps still
    /// repeat once per frame.)
    pub fn frames(&self) -> usize {
        let mut per_step: std::collections::HashMap<usize, usize> = Default::default();
        for e in &self.events {
            *per_step.entry(e.step).or_insert(0) += 1;
        }
        per_step.values().copied().max().unwrap_or(0)
    }

    /// Total traced time across all events, ns.
    pub fn total_ns(&self) -> u64 {
        self.events.iter().map(CallEvent::duration_ns).sum()
    }

    /// Serialize to JSON (the on-disk trace the `courier trace` CLI emits).
    pub fn to_json(&self) -> Result<String> {
        Ok(Json::obj(vec![
            ("program", Json::Str(self.program.clone())),
            ("events", Json::Arr(self.events.iter().map(CallEvent::to_json).collect())),
        ])
        .to_string_pretty())
    }

    /// Parse back from JSON.
    pub fn from_json(s: &str) -> Result<Self> {
        let v = json::parse(s)?;
        Ok(Self {
            program: v.req("program")?.as_str()?.to_string(),
            events: v
                .req("events")?
                .as_arr()?
                .iter()
                .map(CallEvent::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: usize, step: usize, sym: &str) -> CallEvent {
        CallEvent {
            seq,
            step,
            symbol: sym.into(),
            scalars: Vec::new(),
            start_ns: seq as u64 * 10,
            end_ns: seq as u64 * 10 + 5,
            inputs: vec![DataDesc { shape: vec![2, 2], bytes: 16, hash: 0xdead_beef_dead_beef }],
            output: DataDesc { shape: vec![1], bytes: 4, hash: seq as u64 },
        }
    }

    #[test]
    fn frames_counts_step_repetition() {
        let t = Trace {
            program: "p".into(),
            events: vec![ev(0, 0, "a"), ev(1, 1, "b"), ev(2, 0, "a"), ev(3, 1, "b")],
        };
        assert_eq!(t.frames(), 2);
        assert_eq!(t.total_ns(), 20);
    }

    #[test]
    fn frames_counts_partial_first_frame() {
        // tracer attached mid-frame: the first frame only shows steps 2, 3;
        // three full frames follow for those steps — the old
        // smallest-step-repetition rule reported 2, not 3
        let t = Trace {
            program: "p".into(),
            events: vec![
                ev(0, 2, "c"),
                ev(1, 3, "d"),
                ev(2, 0, "a"),
                ev(3, 1, "b"),
                ev(4, 2, "c"),
                ev(5, 3, "d"),
                ev(6, 0, "a"),
                ev(7, 1, "b"),
                ev(8, 2, "c"),
                ev(9, 3, "d"),
            ],
        };
        assert_eq!(t.frames(), 3);
    }

    #[test]
    fn empty_trace() {
        let t = Trace { program: "p".into(), events: vec![] };
        assert_eq!(t.frames(), 0);
        assert_eq!(t.total_ns(), 0);
    }

    #[test]
    fn json_roundtrip_preserves_u64_hashes() {
        let t = Trace { program: "p".into(), events: vec![ev(0, 0, "cv::x")] };
        let s = t.to_json().unwrap();
        let back = Trace::from_json(&s).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.events[0].inputs[0].hash, 0xdead_beef_dead_beef);
    }
}
