//! Call-graph reconstruction from raw events (paper Step 3).
//!
//! Uses only what a binary-level tracer can see: symbols, timestamps and
//! buffer content hashes.  Two calls are causally linked iff an output
//! hash reappears as an input hash later in the same frame — the "looks
//! for the causal function call including input-output data" heuristic.

use std::collections::HashMap;

use super::event::{DataDesc, Trace};

/// A logical function node (one per call site, aggregated over frames).
#[derive(Debug, Clone, PartialEq)]
pub struct FuncNode {
    /// Node id == position in `CallGraph::funcs`.
    pub id: usize,
    /// Call-site step index (chronological rank of first observation).
    pub step: usize,
    /// Library symbol.
    pub symbol: String,
    /// Observations (== frames traced).
    pub calls: usize,
    /// Mean duration over observations, ns.
    pub mean_ns: u64,
    /// Total duration over observations, ns.
    pub total_ns: u64,
}

/// A logical data node: a buffer flowing between two call sites.
#[derive(Debug, Clone, PartialEq)]
pub struct DataNode {
    /// Node id == position in `CallGraph::data`.
    pub id: usize,
    /// Shape observed (stable across frames for a fixed-size binary).
    pub shape: Vec<usize>,
    /// Payload bytes.
    pub bytes: usize,
    /// Producing function node, if any (None == external input).
    pub producer: Option<usize>,
    /// Consuming function nodes (arg position ignored).
    pub consumers: Vec<usize>,
}

/// The reconstructed function call graph including input-output data —
/// the Frontend's deliverable (rendered as Fig. 4 by `ir::to_dot`).
#[derive(Debug, Clone, PartialEq)]
pub struct CallGraph {
    /// Traced binary name.
    pub program: String,
    /// Frames aggregated.
    pub frames: usize,
    /// Function nodes in chronological (step) order.
    pub funcs: Vec<FuncNode>,
    /// Data nodes.
    pub data: Vec<DataNode>,
}

impl CallGraph {
    /// Reconstruct the graph from a trace.
    pub fn from_trace(trace: &Trace) -> Self {
        // Aggregate function stats per call site (step index).
        let mut by_step: HashMap<usize, FuncNode> = HashMap::new();
        for e in &trace.events {
            let node = by_step.entry(e.step).or_insert_with(|| FuncNode {
                id: 0,
                step: e.step,
                symbol: e.symbol.clone(),
                calls: 0,
                mean_ns: 0,
                total_ns: 0,
            });
            node.calls += 1;
            node.total_ns += e.duration_ns();
        }
        let mut funcs: Vec<FuncNode> = by_step.into_values().collect();
        funcs.sort_by_key(|f| f.step);
        for (i, f) in funcs.iter_mut().enumerate() {
            f.id = i;
            f.mean_ns = f.total_ns / f.calls.max(1) as u64;
        }
        let step_to_id: HashMap<usize, usize> =
            funcs.iter().map(|f| (f.step, f.id)).collect();

        // Causality: hash -> producing call site, then match consumer
        // input hashes.  Logical data edges are keyed by
        // (producer site or None, consumer site, arg shape) and
        // deduplicated across frames.
        let mut producer_of_hash: HashMap<u64, usize> = HashMap::new();
        #[allow(clippy::type_complexity)]
        let mut edges: HashMap<(Option<usize>, usize), (DataDesc, Vec<usize>)> = HashMap::new();
        let mut edge_order: Vec<(Option<usize>, usize)> = Vec::new();
        for e in &trace.events {
            let consumer = step_to_id[&e.step];
            for input in &e.inputs {
                let producer = producer_of_hash.get(&input.hash).copied();
                let key_site = producer.map(|p| funcs[p].step);
                let key = (key_site, e.step);
                let entry = edges.entry(key).or_insert_with(|| {
                    edge_order.push(key);
                    (input.clone(), Vec::new())
                });
                if !entry.1.contains(&consumer) {
                    entry.1.push(consumer);
                }
            }
            producer_of_hash.insert(e.output.hash, step_to_id[&e.step]);
        }

        // Terminal outputs: hashes produced but never consumed.
        let consumed: std::collections::HashSet<u64> = trace
            .events
            .iter()
            .flat_map(|e| e.inputs.iter().map(|d| d.hash))
            .collect();
        let mut terminal: Vec<(usize, DataDesc)> = Vec::new();
        let mut seen_terminal: std::collections::HashSet<usize> = Default::default();
        for e in &trace.events {
            if !consumed.contains(&e.output.hash) {
                let fid = step_to_id[&e.step];
                if seen_terminal.insert(fid) {
                    terminal.push((fid, e.output.clone()));
                }
            }
        }

        let mut data = Vec::new();
        for key in &edge_order {
            let (desc, consumers) = &edges[key];
            let producer = key.0.map(|s| step_to_id[&s]);
            data.push(DataNode {
                id: data.len(),
                shape: desc.shape.clone(),
                bytes: desc.bytes,
                producer,
                consumers: consumers.clone(),
            });
        }
        for (fid, desc) in terminal {
            data.push(DataNode {
                id: data.len(),
                shape: desc.shape.clone(),
                bytes: desc.bytes,
                producer: Some(fid),
                consumers: vec![],
            });
        }

        CallGraph {
            program: trace.program.clone(),
            frames: trace.frames(),
            funcs,
            data,
        }
    }

    /// Is the traced flow a simple linear chain (each producer feeds
    /// exactly the next step)?  Linear chains are what the Pipeline
    /// Generator currently handles (the paper defers branches/loops to
    /// future work).
    pub fn is_linear_chain(&self) -> bool {
        for d in &self.data {
            if d.consumers.len() > 1 {
                return false;
            }
            if let (Some(p), Some(&c)) = (d.producer, d.consumers.first()) {
                if c != p + 1 {
                    return false;
                }
            }
        }
        true
    }

    /// Share of total time spent in each function (the "cornerHarris is
    /// 65% of the whole" observation).
    pub fn time_shares(&self) -> Vec<(String, f64)> {
        let total: u64 = self.funcs.iter().map(|f| f.total_ns).sum();
        self.funcs
            .iter()
            .map(|f| (f.symbol.clone(), f.total_ns as f64 / total.max(1) as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::corner_harris_demo;
    use crate::image::synth;
    use crate::trace::trace_program;

    fn graph_for(h: usize, w: usize, frames: usize) -> CallGraph {
        let prog = corner_harris_demo(h, w);
        let inputs: Vec<Vec<crate::image::Mat>> =
            (0..frames).map(|s| vec![synth::noise_rgb(h, w, s as u64)]).collect();
        let t = trace_program(&prog, &inputs).unwrap();
        CallGraph::from_trace(&t)
    }

    #[test]
    fn reconstructs_four_node_chain() {
        let g = graph_for(8, 10, 1);
        assert_eq!(g.funcs.len(), 4);
        assert_eq!(
            g.funcs.iter().map(|f| f.symbol.as_str()).collect::<Vec<_>>(),
            vec!["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"]
        );
        assert!(g.is_linear_chain(), "{g:?}");
    }

    #[test]
    fn aggregates_over_frames() {
        let g = graph_for(8, 10, 3);
        assert_eq!(g.frames, 3);
        for f in &g.funcs {
            assert_eq!(f.calls, 3);
            assert!(f.total_ns >= f.mean_ns);
        }
    }

    #[test]
    fn data_nodes_have_external_input_and_terminal_output() {
        let g = graph_for(8, 10, 1);
        // frame (external, no producer) feeds cvtColor
        let external: Vec<_> = g.data.iter().filter(|d| d.producer.is_none()).collect();
        assert_eq!(external.len(), 1);
        assert_eq!(external[0].consumers, vec![0]);
        assert_eq!(external[0].shape, vec![8, 10, 3]);
        // terminal node produced by the last func, unconsumed
        let terminal: Vec<_> = g.data.iter().filter(|d| d.consumers.is_empty()).collect();
        assert_eq!(terminal.len(), 1);
        assert_eq!(terminal[0].producer, Some(3));
    }

    #[test]
    fn time_shares_sum_to_one() {
        let g = graph_for(16, 16, 2);
        let total: f64 = g.time_shares().iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
