//! Call-graph reconstruction from raw events (paper Step 3).
//!
//! Uses only what a binary-level tracer can see: symbols, timestamps and
//! buffer content hashes.  Two calls are causally linked iff an output
//! hash reappears as an input hash later in the same frame — the "looks
//! for the causal function call including input-output data" heuristic.

use std::collections::HashMap;

use super::event::{DataDesc, Trace};

/// A logical function node (one per call site, aggregated over frames).
#[derive(Debug, Clone, PartialEq)]
pub struct FuncNode {
    /// Node id == position in `CallGraph::funcs`.
    pub id: usize,
    /// Call-site step index (chronological rank of first observation).
    pub step: usize,
    /// Library symbol.
    pub symbol: String,
    /// Per-frame scalar constants observed at the call site (empty for
    /// plain buffer-only calls; stable across frames).
    pub scalars: Vec<f64>,
    /// Observations (== frames traced).
    pub calls: usize,
    /// Mean duration over observations, ns.
    pub mean_ns: u64,
    /// Total duration over observations, ns.
    pub total_ns: u64,
}

/// A logical data node: a buffer flowing between two call sites.
#[derive(Debug, Clone, PartialEq)]
pub struct DataNode {
    /// Node id == position in `CallGraph::data`.
    pub id: usize,
    /// Shape observed (stable across frames for a fixed-size binary).
    pub shape: Vec<usize>,
    /// Payload bytes.
    pub bytes: usize,
    /// Producing function node, if any (None == external input).
    pub producer: Option<usize>,
    /// Consuming function nodes (arg position ignored).
    pub consumers: Vec<usize>,
}

/// The reconstructed function call graph including input-output data —
/// the Frontend's deliverable (rendered as Fig. 4 by `ir::to_dot`).
#[derive(Debug, Clone, PartialEq)]
pub struct CallGraph {
    /// Traced binary name.
    pub program: String,
    /// Frames aggregated.
    pub frames: usize,
    /// Function nodes in chronological (step) order.
    pub funcs: Vec<FuncNode>,
    /// Data nodes.
    pub data: Vec<DataNode>,
}

impl CallGraph {
    /// Reconstruct the graph from a trace.
    pub fn from_trace(trace: &Trace) -> Self {
        // Aggregate function stats per call site (step index).
        let mut by_step: HashMap<usize, FuncNode> = HashMap::new();
        for e in &trace.events {
            let node = by_step.entry(e.step).or_insert_with(|| FuncNode {
                id: 0,
                step: e.step,
                symbol: e.symbol.clone(),
                scalars: e.scalars.clone(),
                calls: 0,
                mean_ns: 0,
                total_ns: 0,
            });
            node.calls += 1;
            node.total_ns += e.duration_ns();
        }
        let mut funcs: Vec<FuncNode> = by_step.into_values().collect();
        funcs.sort_by_key(|f| f.step);
        for (i, f) in funcs.iter_mut().enumerate() {
            f.id = i;
            f.mean_ns = f.total_ns / f.calls.max(1) as u64;
        }
        let step_to_id: HashMap<usize, usize> =
            funcs.iter().map(|f| (f.step, f.id)).collect();

        // Causality: hash -> producing call site, then match consumer
        // input hashes.  Logical data edges are keyed by
        // (producer site or None, consumer site, arg shape) and
        // deduplicated across frames.
        // A tracer attached mid-frame records a partial first frame whose
        // inputs' real producers ran before the attach: reconstructing
        // edges from those events would fabricate external inputs (and
        // extra argument slots) for interior steps.  When a later frame
        // boundary proves the trace starts mid-frame, the leading partial
        // frame is excluded from edge reconstruction (function timing
        // stats above still use every event).
        let min_step = trace.events.iter().map(|e| e.step).min().unwrap_or(0);
        let skip = if trace.events.first().is_some_and(|e| e.step != min_step) {
            trace
                .events
                .windows(2)
                .position(|w| w[1].step <= w[0].step)
                .map(|i| i + 1)
                .unwrap_or(0)
        } else {
            0
        };

        let mut producer_of_hash: HashMap<u64, usize> = HashMap::new();
        // Logical edges are keyed by (producer site, consumer site, arg
        // position): the arg position keeps a call that reads the same
        // buffer in two argument slots (f(x, x)) as two edges — the
        // duplicate-edge wiring the plan layer explicitly supports —
        // while still deduplicating across frames.
        #[allow(clippy::type_complexity)]
        let mut edges: HashMap<(Option<usize>, usize, usize), (DataDesc, Vec<usize>)> =
            HashMap::new();
        let mut edge_order: Vec<(Option<usize>, usize, usize)> = Vec::new();
        let mut prev_step: Option<usize> = None;
        for e in &trace.events[skip..] {
            // Frame boundary: call sites replay in ascending step order
            // within one frame, so a non-increasing step index means a new
            // frame began.  Producer hashes must not survive the boundary:
            // an output hash from frame N matching an input in frame N+1
            // would fabricate a cross-frame (often *backwards*) edge the
            // "later in the same frame" rule above explicitly excludes.
            if prev_step.is_some_and(|prev| e.step <= prev) {
                producer_of_hash.clear();
            }
            prev_step = Some(e.step);
            let consumer = step_to_id[&e.step];
            for (arg_pos, input) in e.inputs.iter().enumerate() {
                let producer = producer_of_hash.get(&input.hash).copied();
                let key_site = producer.map(|p| funcs[p].step);
                let key = (key_site, e.step, arg_pos);
                let entry = edges.entry(key).or_insert_with(|| {
                    edge_order.push(key);
                    (input.clone(), Vec::new())
                });
                if !entry.1.contains(&consumer) {
                    entry.1.push(consumer);
                }
            }
            producer_of_hash.insert(e.output.hash, step_to_id[&e.step]);
        }

        // Terminal outputs: hashes produced but never consumed *within
        // their own frame* — the same per-frame scoping as the edge
        // reconstruction above, so a cross-frame hash collision neither
        // suppresses a genuine terminal nor fabricates one.  A trailing
        // partial frame (tracer detached mid-frame) is excluded when a
        // complete frame exists: its truncation point would otherwise
        // fabricate a mid-chain terminal.
        let mut terminal: Vec<(usize, DataDesc)> = Vec::new();
        let mut seen_terminal: std::collections::HashSet<usize> = Default::default();
        let windowed = &trace.events[skip..];
        let max_step = windowed.iter().map(|e| e.step).max().unwrap_or(0);
        let mut frame_start = 0usize;
        while frame_start < windowed.len() {
            let mut end = frame_start + 1;
            while end < windowed.len() && windowed[end].step > windowed[end - 1].step {
                end += 1;
            }
            let frame = &windowed[frame_start..end];
            let trailing_partial = end == windowed.len()
                && frame_start > 0
                && frame.last().is_some_and(|e| e.step < max_step);
            if !trailing_partial {
                let consumed: std::collections::HashSet<u64> =
                    frame.iter().flat_map(|e| e.inputs.iter().map(|d| d.hash)).collect();
                for e in frame {
                    if !consumed.contains(&e.output.hash) {
                        let fid = step_to_id[&e.step];
                        if seen_terminal.insert(fid) {
                            terminal.push((fid, e.output.clone()));
                        }
                    }
                }
            }
            frame_start = end;
        }

        let mut data = Vec::new();
        for key in &edge_order {
            let (desc, consumers) = &edges[key];
            let producer: Option<usize> = key.0.map(|s| step_to_id[&s]);
            data.push(DataNode {
                id: data.len(),
                shape: desc.shape.clone(),
                bytes: desc.bytes,
                producer,
                consumers: consumers.clone(),
            });
        }
        for (fid, desc) in terminal {
            data.push(DataNode {
                id: data.len(),
                shape: desc.shape.clone(),
                bytes: desc.bytes,
                producer: Some(fid),
                consumers: vec![],
            });
        }

        CallGraph {
            program: trace.program.clone(),
            frames: trace.frames(),
            funcs,
            data,
        }
    }

    /// Is the traced flow a simple linear chain (each producer feeds
    /// exactly the next step)?  The Pipeline Generator handles DAGs too;
    /// linear chains additionally keep the pre-DAG plan serialization
    /// byte-for-byte.
    pub fn is_linear_chain(&self) -> bool {
        for d in &self.data {
            if d.consumers.len() > 1 {
                return false;
            }
            if let (Some(p), Some(&c)) = (d.producer, d.consumers.first()) {
                if c != p + 1 {
                    return false;
                }
            }
        }
        true
    }

    /// Share of total time spent in each function (the "cornerHarris is
    /// 65% of the whole" observation).
    pub fn time_shares(&self) -> Vec<(String, f64)> {
        let total: u64 = self.funcs.iter().map(|f| f.total_ns).sum();
        self.funcs
            .iter()
            .map(|f| (f.symbol.clone(), f.total_ns as f64 / total.max(1) as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::corner_harris_demo;
    use crate::image::synth;
    use crate::trace::trace_program;

    fn graph_for(h: usize, w: usize, frames: usize) -> CallGraph {
        let prog = corner_harris_demo(h, w);
        let inputs: Vec<Vec<crate::image::Mat>> =
            (0..frames).map(|s| vec![synth::noise_rgb(h, w, s as u64)]).collect();
        let t = trace_program(&prog, &inputs).unwrap();
        CallGraph::from_trace(&t)
    }

    #[test]
    fn reconstructs_four_node_chain() {
        let g = graph_for(8, 10, 1);
        assert_eq!(g.funcs.len(), 4);
        assert_eq!(
            g.funcs.iter().map(|f| f.symbol.as_str()).collect::<Vec<_>>(),
            vec!["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"]
        );
        assert!(g.is_linear_chain(), "{g:?}");
    }

    #[test]
    fn aggregates_over_frames() {
        let g = graph_for(8, 10, 3);
        assert_eq!(g.frames, 3);
        for f in &g.funcs {
            assert_eq!(f.calls, 3);
            assert!(f.total_ns >= f.mean_ns);
        }
    }

    #[test]
    fn data_nodes_have_external_input_and_terminal_output() {
        let g = graph_for(8, 10, 1);
        // frame (external, no producer) feeds cvtColor
        let external: Vec<_> = g.data.iter().filter(|d| d.producer.is_none()).collect();
        assert_eq!(external.len(), 1);
        assert_eq!(external[0].consumers, vec![0]);
        assert_eq!(external[0].shape, vec![8, 10, 3]);
        // terminal node produced by the last func, unconsumed
        let terminal: Vec<_> = g.data.iter().filter(|d| d.consumers.is_empty()).collect();
        assert_eq!(terminal.len(), 1);
        assert_eq!(terminal[0].producer, Some(3));
    }

    #[test]
    fn time_shares_sum_to_one() {
        let g = graph_for(16, 16, 2);
        let total: f64 = g.time_shares().iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    fn raw_event(
        seq: usize,
        step: usize,
        sym: &str,
        in_hashes: &[u64],
        out_hash: u64,
    ) -> crate::trace::CallEvent {
        let d = |hash: u64| DataDesc { shape: vec![4, 4], bytes: 64, hash };
        crate::trace::CallEvent {
            seq,
            step,
            symbol: sym.into(),
            scalars: Vec::new(),
            start_ns: seq as u64 * 100,
            end_ns: seq as u64 * 100 + 10,
            inputs: in_hashes.iter().map(|&h| d(h)).collect(),
            output: d(out_hash),
        }
    }

    #[test]
    fn cross_frame_hash_reuse_does_not_fabricate_edges() {
        // Frame 1: a(ext 0x10) -> 0xA, b(0xA) -> 0xB.
        // Frame 2: a's external input happens to hash 0xB — identical to
        // frame 1's *output* of b.  Without the per-frame reset this
        // matched b as the producer of a, a backwards b -> a edge across
        // the frame boundary.
        let t = Trace {
            program: "leak".into(),
            events: vec![
                raw_event(0, 0, "a", &[0x10], 0xA),
                raw_event(1, 1, "b", &[0xA], 0xB),
                raw_event(2, 0, "a", &[0xB], 0xC),
                raw_event(3, 1, "b", &[0xC], 0xD),
            ],
        };
        let g = CallGraph::from_trace(&t);
        for d in &g.data {
            if d.consumers.contains(&0) {
                assert_eq!(
                    d.producer, None,
                    "step 0's input must stay external, got fabricated edge: {d:?}"
                );
            }
            if let (Some(p), Some(&c)) = (d.producer, d.consumers.first()) {
                assert!(p < c, "backwards edge {p} -> {c} leaked across frames: {d:?}");
            }
        }
    }

    #[test]
    fn mid_frame_attach_excludes_partial_leading_frame_from_edges() {
        // attach lands mid-frame: steps 2,3 of frame 0 are recorded, then
        // two complete frames.  The partial frame's step-2 input has no
        // visible producer; without the skip it fabricated an extra
        // external (None, 2) edge that made unary step 2 look binary.
        let chain = |seq0: usize, frame: u64, steps: std::ops::Range<usize>| {
            let start = steps.start;
            steps
                .map(|s| {
                    let base = frame * 0x100;
                    raw_event(
                        seq0 + s - start,
                        s,
                        "f",
                        &[base + s as u64],
                        base + s as u64 + 1,
                    )
                })
                .collect::<Vec<_>>()
        };
        let mut events = chain(0, 0, 2..4);
        events.extend(chain(2, 1, 0..4));
        events.extend(chain(6, 2, 0..4));
        let t = Trace { program: "midframe".into(), events };
        assert_eq!(t.frames(), 3);
        let g = CallGraph::from_trace(&t);
        assert_eq!(g.funcs.len(), 4);
        // step 2 is fed by exactly one data node, produced by step 1
        let into2: Vec<_> = g.data.iter().filter(|d| d.consumers.contains(&2)).collect();
        assert_eq!(into2.len(), 1, "fabricated edge from the partial frame: {into2:?}");
        assert_eq!(into2[0].producer, Some(1));
        // only the true head consumes the external input
        for d in &g.data {
            if d.producer.is_none() {
                assert_eq!(d.consumers, vec![0], "{d:?}");
            }
        }
    }

    #[test]
    fn trailing_partial_frame_does_not_fabricate_terminals() {
        // one complete a->b->c->d frame, then the tracer detaches after
        // step 1 of the next frame: the truncation point must not appear
        // as a mid-chain terminal output
        let events = vec![
            raw_event(0, 0, "a", &[0x10], 0x11),
            raw_event(1, 1, "b", &[0x11], 0x12),
            raw_event(2, 2, "c", &[0x12], 0x13),
            raw_event(3, 3, "d", &[0x13], 0x14),
            raw_event(4, 0, "a", &[0x20], 0x21),
            raw_event(5, 1, "b", &[0x21], 0x22),
        ];
        let t = Trace { program: "detach".into(), events };
        let g = CallGraph::from_trace(&t);
        let terminals: Vec<_> = g.data.iter().filter(|d| d.consumers.is_empty()).collect();
        assert_eq!(terminals.len(), 1, "detach fabricated a terminal: {terminals:?}");
        assert_eq!(terminals[0].producer, Some(3));
    }

    #[test]
    fn reconstructs_harris_shaped_dag() {
        let prog = crate::app::harris_dag_demo(8, 10);
        let inputs = vec![vec![synth::noise_rgb(8, 10, 0)]];
        let t = trace_program(&prog, &inputs).unwrap();
        let g = CallGraph::from_trace(&t);
        assert_eq!(g.funcs.len(), 6);
        assert!(!g.is_linear_chain(), "harris DAG must not look linear: {g:?}");
        // gray (produced by step 0) fans out to sobel x (1) and sobel y (2)
        let fanout: Vec<_> = g.data.iter().filter(|d| d.producer == Some(0)).collect();
        let consumed_by: Vec<usize> =
            fanout.iter().flat_map(|d| d.consumers.iter().copied()).collect();
        assert!(consumed_by.contains(&1) && consumed_by.contains(&2), "{fanout:?}");
        // the corner response (step 3) consumes both gradients
        let into_resp: Vec<_> =
            g.data.iter().filter(|d| d.consumers.contains(&3)).collect();
        assert_eq!(into_resp.len(), 2, "{into_resp:?}");
        assert_eq!(into_resp[0].producer, Some(1), "arg order must be Ix first");
        assert_eq!(into_resp[1].producer, Some(2), "arg order must be Iy second");
    }
}
