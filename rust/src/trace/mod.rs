//! Frontend: dynamic analysis of the running binary (paper Steps 1–3).
//!
//! The [`Tracer`] is an interposing [`Dispatch`] — the `LD_PRELOAD` shim.
//! It forwards every call to the real library while recording a
//! [`CallEvent`]: symbol, wall-clock start/end, and a content hash of each
//! input/output buffer.  From those events alone (no program source), the
//! graph builder reconstructs the *causal function call graph including
//! input-output data*: two calls are connected iff one's output hash
//! equals the other's input hash.

mod event;
mod graph;
mod profile;

pub use event::{CallEvent, DataDesc, Trace};
pub use graph::{CallGraph, DataNode, FuncNode};
pub use profile::{FunctionProfile, Profile};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::app::{CallSite, Dispatch};
use crate::image::{sampled_hash, Mat};
use crate::Result;

/// Interposing dispatch that records every library call.
pub struct Tracer {
    inner: Arc<dyn Dispatch>,
    epoch: Instant,
    events: Mutex<Vec<CallEvent>>,
}

impl Tracer {
    /// Wrap an existing dispatch (usually `RegistryDispatch`).
    pub fn new(inner: Arc<dyn Dispatch>) -> Arc<Self> {
        Arc::new(Self {
            inner,
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
        })
    }

    /// Number of recorded events so far.
    pub fn event_count(&self) -> usize {
        self.events.lock().expect("tracer lock").len()
    }

    /// Snapshot the recorded trace.
    pub fn trace(&self, program_name: &str) -> Trace {
        Trace {
            program: program_name.to_string(),
            events: self.events.lock().expect("tracer lock").clone(),
        }
    }

    /// Clear recorded events (e.g. to skip a warm-up frame, like the paper
    /// ignoring the one-time `imread`).
    pub fn reset(&self) {
        self.events.lock().expect("tracer lock").clear();
    }
}

impl Dispatch for Tracer {
    fn call(&self, site: CallSite<'_>, args: &[&Mat]) -> Result<Mat> {
        let inputs: Vec<DataDesc> = args.iter().map(|m| DataDesc::of(m)).collect();
        let start = self.epoch.elapsed().as_nanos() as u64;
        let out = self.inner.call(site, args)?;
        let end = self.epoch.elapsed().as_nanos() as u64;
        let event = CallEvent {
            seq: 0, // fixed up under the lock below
            step: site.step,
            symbol: site.symbol.to_string(),
            scalars: site.scalars.to_vec(),
            start_ns: start,
            end_ns: end,
            inputs,
            output: DataDesc::of(&out),
        };
        let mut events = self.events.lock().expect("tracer lock");
        let mut event = event;
        event.seq = events.len();
        events.push(event);
        Ok(out)
    }
}

/// Convenience: run `frames` through `program` under a tracer over the
/// standard library and return the trace (Steps 1–2 in one call).
pub fn trace_program(
    program: &crate::app::Program,
    frames: &[Vec<Mat>],
) -> Result<Trace> {
    let tracer = Tracer::new(Arc::new(crate::app::RegistryDispatch::standard()));
    let interp = crate::app::Interpreter::new(program.clone(), tracer.clone());
    for frame in frames {
        interp.run(frame)?;
    }
    Ok(tracer.trace(&program.name))
}

/// Hash helper re-exported for tests.
pub fn hash_of(m: &Mat) -> u64 {
    sampled_hash(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{corner_harris_demo, Interpreter, RegistryDispatch};
    use crate::image::synth;

    #[test]
    fn tracer_records_all_calls_in_order() {
        let prog = corner_harris_demo(8, 10);
        let tracer = Tracer::new(Arc::new(RegistryDispatch::standard()));
        let interp = Interpreter::new(prog, tracer.clone());
        interp.run(&[synth::noise_rgb(8, 10, 0)]).unwrap();
        let t = tracer.trace("cornerHarris_Demo");
        assert_eq!(t.events.len(), 4);
        let syms: Vec<&str> = t.events.iter().map(|e| e.symbol.as_str()).collect();
        assert_eq!(
            syms,
            vec!["cv::cvtColor", "cv::cornerHarris", "cv::normalize", "cv::convertScaleAbs"]
        );
        // timestamps are monotone and inclusive
        for w in t.events.windows(2) {
            assert!(w[0].end_ns <= w[1].start_ns);
        }
        for e in &t.events {
            assert!(e.start_ns <= e.end_ns);
        }
    }

    #[test]
    fn hashes_link_producer_to_consumer() {
        let prog = corner_harris_demo(8, 10);
        let tracer = Tracer::new(Arc::new(RegistryDispatch::standard()));
        let interp = Interpreter::new(prog, tracer.clone());
        interp.run(&[synth::noise_rgb(8, 10, 1)]).unwrap();
        let t = tracer.trace("x");
        // cvtColor's output is cornerHarris's input
        assert_eq!(t.events[0].output.hash, t.events[1].inputs[0].hash);
        assert_eq!(t.events[1].output.hash, t.events[2].inputs[0].hash);
    }

    #[test]
    fn reset_clears() {
        let prog = corner_harris_demo(8, 10);
        let tracer = Tracer::new(Arc::new(RegistryDispatch::standard()));
        let interp = Interpreter::new(prog, tracer.clone());
        interp.run(&[synth::noise_rgb(8, 10, 0)]).unwrap();
        assert_eq!(tracer.event_count(), 4);
        tracer.reset();
        assert_eq!(tracer.event_count(), 0);
    }

    #[test]
    fn trace_program_helper_multi_frame() {
        let prog = corner_harris_demo(8, 10);
        let frames: Vec<Vec<Mat>> = (0..3).map(|s| vec![synth::noise_rgb(8, 10, s)]).collect();
        let t = trace_program(&prog, &frames).unwrap();
        assert_eq!(t.events.len(), 12);
        assert_eq!(t.frames(), 3);
    }
}
