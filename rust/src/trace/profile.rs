//! Per-function runtime profile — the numbers the Pipeline Generator's
//! partition policy consumes ("processing time of software functions can
//! be obtained in the analyzed data from the Frontend").

use super::event::Trace;
use super::graph::CallGraph;

/// Aggregated statistics for one call site.
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionProfile {
    /// Call-site step index.
    pub step: usize,
    /// Library symbol.
    pub symbol: String,
    /// Observations.
    pub calls: usize,
    /// Mean duration, ns.
    pub mean_ns: u64,
    /// Min duration, ns.
    pub min_ns: u64,
    /// Max duration, ns.
    pub max_ns: u64,
    /// Mean input payload, bytes.
    pub input_bytes: usize,
    /// Mean output payload, bytes.
    pub output_bytes: usize,
}

/// Profile of a whole traced binary.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    /// Traced binary name.
    pub program: String,
    /// Frames observed.
    pub frames: usize,
    /// Per-call-site stats in step order.
    pub functions: Vec<FunctionProfile>,
}

impl Profile {
    /// Build from a raw trace.
    pub fn from_trace(trace: &Trace) -> Self {
        use std::collections::BTreeMap;
        let mut agg: BTreeMap<usize, FunctionProfile> = BTreeMap::new();
        let mut counts: BTreeMap<usize, (u64, usize, usize)> = BTreeMap::new();
        for e in &trace.events {
            let p = agg.entry(e.step).or_insert_with(|| FunctionProfile {
                step: e.step,
                symbol: e.symbol.clone(),
                calls: 0,
                mean_ns: 0,
                min_ns: u64::MAX,
                max_ns: 0,
                input_bytes: 0,
                output_bytes: 0,
            });
            let d = e.duration_ns();
            p.calls += 1;
            p.min_ns = p.min_ns.min(d);
            p.max_ns = p.max_ns.max(d);
            let c = counts.entry(e.step).or_insert((0, 0, 0));
            c.0 += d;
            c.1 += e.inputs.iter().map(|i| i.bytes).sum::<usize>();
            c.2 += e.output.bytes;
        }
        for (step, p) in agg.iter_mut() {
            let (total, ib, ob) = counts[step];
            p.mean_ns = total / p.calls.max(1) as u64;
            p.input_bytes = ib / p.calls.max(1);
            p.output_bytes = ob / p.calls.max(1);
        }
        Profile {
            program: trace.program.clone(),
            frames: trace.frames(),
            functions: agg.into_values().collect(),
        }
    }

    /// Build from an already-reconstructed graph (mean times only).
    pub fn from_graph(graph: &CallGraph) -> Self {
        Profile {
            program: graph.program.clone(),
            frames: graph.frames,
            functions: graph
                .funcs
                .iter()
                .map(|f| FunctionProfile {
                    step: f.step,
                    symbol: f.symbol.clone(),
                    calls: f.calls,
                    mean_ns: f.mean_ns,
                    min_ns: f.mean_ns,
                    max_ns: f.mean_ns,
                    input_bytes: 0,
                    output_bytes: 0,
                })
                .collect(),
        }
    }

    /// Total mean frame time, ns (sum over call sites).
    pub fn frame_ns(&self) -> u64 {
        self.functions.iter().map(|f| f.mean_ns).sum()
    }

    /// Mean time of one symbol, if present.
    pub fn mean_ns_of(&self, symbol: &str) -> Option<u64> {
        self.functions.iter().find(|f| f.symbol == symbol).map(|f| f.mean_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::corner_harris_demo;
    use crate::image::synth;
    use crate::trace::trace_program;

    #[test]
    fn profile_aggregates_frames() {
        let prog = corner_harris_demo(16, 16);
        let frames: Vec<_> = (0..4).map(|s| vec![synth::noise_rgb(16, 16, s)]).collect();
        let t = trace_program(&prog, &frames).unwrap();
        let p = Profile::from_trace(&t);
        assert_eq!(p.frames, 4);
        assert_eq!(p.functions.len(), 4);
        for f in &p.functions {
            assert_eq!(f.calls, 4);
            assert!(f.min_ns <= f.mean_ns && f.mean_ns <= f.max_ns);
        }
        assert!(p.frame_ns() > 0);
        assert!(p.mean_ns_of("cv::cornerHarris").is_some());
        assert!(p.mean_ns_of("cv::nope").is_none());
    }

    #[test]
    fn io_bytes_recorded() {
        let prog = corner_harris_demo(8, 8);
        let t = trace_program(&prog, &[vec![synth::noise_rgb(8, 8, 0)]]).unwrap();
        let p = Profile::from_trace(&t);
        // cvtColor: input (8,8,3) f32 = 768 B, output (8,8) f32 = 256 B
        let f = &p.functions[0];
        assert_eq!(f.input_bytes, 768);
        assert_eq!(f.output_bytes, 256);
    }
}
