//! Calibration: replay real frames through a built pipeline, compare the
//! measured per-stage latencies against the simulator's prediction, and
//! record per-task corrections into the [`CalibratedCostDb`].
//!
//! Stage-level measurements are attributed to tasks proportionally to
//! their static estimates (the runtime's [`PipelineStats`] spans are
//! per-stage, not per-task: a stage executes its tasks back to back in
//! one filter body).

use crate::image::Mat;
use crate::ir::Ir;
use crate::metrics::TunerMetrics;
use crate::pipeline::{primary_input_shapes, simulate, BuiltPipeline, PipelineStats};
use crate::{CourierError, Result};

use super::cost_db::CalibratedCostDb;

/// One stage's predicted-vs-measured comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct StageCalibration {
    /// Stage index.
    pub stage: usize,
    /// Static estimate (sum of task estimates), ns/frame.
    pub est_ns: u64,
    /// Simulator's per-frame busy time, ns/frame.
    pub sim_ns: u64,
    /// Measured per-frame busy time, ns/frame.
    pub measured_ns: u64,
}

/// The deliverable of one calibration pass.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationRun {
    /// Program the pipeline was built for.
    pub program: String,
    /// Frames replayed.
    pub frames: u64,
    /// Measured wall clock of the whole replay, ns.
    pub wall_ns: u64,
    /// Per-stage comparison rows.
    pub stages: Vec<StageCalibration>,
}

impl CalibrationRun {
    /// Measured per-frame wall clock, ms.
    pub fn wall_ms_per_frame(&self) -> f64 {
        if self.frames == 0 {
            return 0.0;
        }
        self.wall_ns as f64 / self.frames as f64 / 1e6
    }

    /// Ratio of total measured to total predicted stage time (how far the
    /// whole static model is off for this program).
    pub fn overall_factor(&self) -> f64 {
        let est: u64 = self.stages.iter().map(|s| s.est_ns).sum();
        let measured: u64 = self.stages.iter().map(|s| s.measured_ns).sum();
        if est == 0 {
            return 1.0;
        }
        measured as f64 / est as f64
    }
}

/// Replay `frames` through `built`, fold per-task measurements into `db`,
/// and return the per-stage comparison.
///
/// `ir` must be the IR the pipeline was built from — calibration keys are
/// derived from the same per-task input shapes the builder placed with.
/// `static_ns` must be the **uncalibrated** per-task estimates in flat
/// task order (the plan's own estimates may already carry calibration;
/// recorded factors anchor to the static model — see
/// [`CalibratedCostDb::record`]).
pub fn calibrate(
    built: &BuiltPipeline,
    ir: &Ir,
    frames: Vec<Mat>,
    static_ns: &[u64],
    db: &mut CalibratedCostDb,
    metrics: &TunerMetrics,
) -> Result<CalibrationRun> {
    if frames.is_empty() {
        return Err(CourierError::Other("calibration needs at least one frame".into()));
    }
    let n_frames = frames.len() as u64;
    let shapes = primary_input_shapes(ir)?;
    let flat_tasks: Vec<_> = built.plan.stages.iter().flat_map(|s| &s.tasks).collect();
    if flat_tasks.len() != shapes.len() || flat_tasks.len() != static_ns.len() {
        return Err(CourierError::Other(format!(
            "calibration: plan has {} tasks, IR has {} functions, {} static estimates",
            flat_tasks.len(),
            shapes.len(),
            static_ns.len()
        )));
    }

    // Warm the pipeline's buffer pool with one frame before timing: the
    // first frame's pool misses (and lazy per-shape shelf growth) are a
    // cold-start artifact, and calibration factors must reflect the
    // steady state the plan will actually serve.
    let _ = built.process_one(frames[0].clone())?;

    // The replay records through the pipeline's trace sink like any
    // other run, so a calibration pass also leaves spans behind for
    // `obs::attribute`/`obs::drift` to decompose.
    let t0 = std::time::Instant::now();
    let (_, stats): (_, PipelineStats) = built.run(frames)?;
    metrics.measure_time.record(t0.elapsed());
    metrics.measured_runs.inc();

    let sim = metrics.sim_time.time(|| {
        simulate(&built.plan, n_frames, built.plan.threads.max(1), built.plan.tokens.max(1))
    });

    let mut rows = Vec::with_capacity(built.plan.stages.len());
    let mut task_idx = 0usize;
    for (si, stage) in built.plan.stages.iter().enumerate() {
        // the plan's own estimates may be calibrated (a seeded tune
        // builds the pipeline through the calibration layer) — report
        // rows compare measurement against the *static* model, so the
        // overall factor keeps meaning measured/static
        let est_ns = stage.est_ns();
        let static_est_ns: u64 =
            static_ns[task_idx..task_idx + stage.tasks.len()].iter().sum();
        let measured_ns = stats.stage_busy_ns(si) / n_frames;
        let sim_ns = sim.stage_busy_ns[si] / n_frames;
        rows.push(StageCalibration { stage: si, est_ns: static_est_ns, sim_ns, measured_ns });

        // attribute the stage measurement to its tasks proportionally
        for task in &stage.tasks {
            let task_measured = if est_ns == 0 {
                measured_ns / stage.tasks.len().max(1) as u64
            } else {
                (measured_ns as u128 * task.est_ns as u128 / est_ns as u128) as u64
            };
            let key = task.calibration_key(&shapes[task_idx]);
            db.record(&key, &task.symbol, static_ns[task_idx], task_measured.max(1));
            metrics.calibration_samples.inc();
            task_idx += 1;
        }
    }

    Ok(CalibrationRun {
        program: built.plan.program.clone(),
        frames: n_frames,
        wall_ns: stats.wall_ns,
        stages: rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::corner_harris_demo;
    use crate::config::Config;
    use crate::hwdb::HwDatabase;
    use crate::image::synth;
    use crate::runtime::Runtime;
    use crate::swlib::Registry;
    use crate::trace::{trace_program, CallGraph};
    use crate::util::testing::TempDir;

    fn hermetic_build(h: usize, w: usize) -> (BuiltPipeline, Ir, TempDir) {
        let tmp = crate::util::testing::empty_hwdb_dir("calibrate").unwrap();
        let db = HwDatabase::load(tmp.path()).unwrap();
        let prog = corner_harris_demo(h, w);
        let trace = trace_program(&prog, &[vec![synth::noise_rgb(h, w, 0)]]).unwrap();
        let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
        let cfg = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };
        let built = crate::pipeline::build(
            &ir,
            &db,
            &Runtime::cpu().unwrap(),
            &Registry::standard(),
            &cfg,
        )
        .unwrap();
        (built, ir, tmp)
    }

    fn static_ests(built: &BuiltPipeline) -> Vec<u64> {
        // the hermetic build is uncalibrated, so its plan estimates ARE
        // the static estimates
        built.plan.stages.iter().flat_map(|s| &s.tasks).map(|t| t.est_ns).collect()
    }

    #[test]
    fn calibration_records_every_task() {
        let (built, ir, _tmp) = hermetic_build(24, 32);
        let mut db = CalibratedCostDb::new();
        let metrics = TunerMetrics::default();
        let frames: Vec<Mat> = (0..4).map(|s| synth::noise_rgb(24, 32, s)).collect();
        let run = calibrate(&built, &ir, frames, &static_ests(&built), &mut db, &metrics).unwrap();

        assert_eq!(run.frames, 4);
        assert_eq!(run.stages.len(), built.plan.stages.len());
        assert_eq!(db.len(), ir.funcs.len(), "one record per task");
        assert_eq!(metrics.calibration_samples.get(), ir.funcs.len() as u64);
        assert_eq!(metrics.measured_runs.get(), 1);
        assert!(run.overall_factor() > 0.0);
        assert!(
            built.sink.recorded() > 0,
            "calibration replays must record spans through the pipeline's trace sink"
        );
        // keys embed the per-task input shape and placement (CPU here)
        assert!(db.get("cv::cvtColor@24x32x3#sw").is_some());
        assert!(db.get("cv::cornerHarris@24x32#sw").is_some());
    }

    #[test]
    fn calibration_rejects_empty_stream() {
        let (built, ir, _tmp) = hermetic_build(16, 16);
        let mut db = CalibratedCostDb::new();
        let ests = static_ests(&built);
        assert!(
            calibrate(&built, &ir, vec![], &ests, &mut db, &TunerMetrics::default()).is_err()
        );
    }

    #[test]
    fn calibration_rejects_mismatched_static_estimates() {
        let (built, ir, _tmp) = hermetic_build(16, 16);
        let mut db = CalibratedCostDb::new();
        let frames: Vec<Mat> = vec![synth::noise_rgb(16, 16, 0)];
        assert!(
            calibrate(&built, &ir, frames, &[1, 2], &mut db, &TunerMetrics::default()).is_err()
        );
    }
}
