//! The calibrated cost database: measured per-task latencies, persisted
//! as an `hwdb`-style JSON manifest.
//!
//! The hardware database records what the synthesis model *predicts*;
//! this database records what replaying real frames *measured*, keyed by
//! [`crate::hlo::task_key`] (`symbol@HxW[xC]#hw|sw` — placement-scoped,
//! so CPU measurements never land on fabric estimates).  The ratio
//! between the two is the calibration factor fed back into the builder
//! through [`crate::hlo::CostCalibration`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::hlo::CostCalibration;
use crate::util::json::{self, Json};
use crate::Result;

/// Schema version written by [`CalibratedCostDb::to_json`].
pub const COST_DB_VERSION: u32 = 1;

/// One task's calibration record.
#[derive(Debug, Clone, PartialEq)]
pub struct CostRecord {
    /// Library symbol (redundant with the key prefix; kept for reports).
    pub symbol: String,
    /// The static estimate at the most recent recording, ns.
    pub predicted_ns: u64,
    /// Running mean of measured per-frame latency, ns.
    pub measured_ns: u64,
    /// Measurements folded into the mean.
    pub samples: u64,
}

impl CostRecord {
    /// `measured / predicted` — how far reality diverged from the model.
    pub fn factor(&self) -> f64 {
        if self.predicted_ns == 0 {
            return 1.0;
        }
        self.measured_ns as f64 / self.predicted_ns as f64
    }
}

/// The persistent calibration store (BTreeMap: serialization and report
/// ordering stay deterministic).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibratedCostDb {
    records: BTreeMap<String, CostRecord>,
}

impl CalibratedCostDb {
    /// Empty database.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one measurement into the record for `key` (running mean over
    /// `samples`).  `predicted_ns` must be the **static** (uncalibrated)
    /// estimate — the factor is `measured / static`, which is what the
    /// builder multiplies static estimates by; feeding an
    /// already-calibrated value in would make the factor self-referential
    /// and oscillate the applied correction.
    ///
    /// A *substantially* changed static prediction (e.g. the hardware
    /// database was re-synthesized with different cycle estimates)
    /// restarts the record: the old measurements calibrated a baseline
    /// that no longer exists, and keeping their mean would skew the new
    /// estimate by the old model's error forever.  The drift band
    /// (±1/3) matters because software predictions are *traced means*
    /// that jitter a few percent between runs — exact-equality would
    /// restart every SW record on every tune and samples would never
    /// accumulate.
    pub fn record(&mut self, key: &str, symbol: &str, predicted_ns: u64, measured_ns: u64) {
        match self.records.get_mut(key) {
            Some(r)
                if {
                    let drift =
                        predicted_ns.max(1) as f64 / r.predicted_ns.max(1) as f64;
                    (0.75..=4.0 / 3.0).contains(&drift)
                } =>
            {
                let total = r.measured_ns as u128 * r.samples as u128 + measured_ns as u128;
                r.samples += 1;
                r.measured_ns = (total / r.samples as u128) as u64;
            }
            Some(r) => {
                *r = CostRecord {
                    symbol: symbol.to_string(),
                    predicted_ns,
                    measured_ns,
                    samples: 1,
                };
            }
            None => {
                self.records.insert(
                    key.to_string(),
                    CostRecord {
                        symbol: symbol.to_string(),
                        predicted_ns,
                        measured_ns,
                        samples: 1,
                    },
                );
            }
        }
    }

    /// The record for one key.
    pub fn get(&self, key: &str) -> Option<&CostRecord> {
        self.records.get(key)
    }

    /// All records in key order.
    pub fn records(&self) -> impl Iterator<Item = (&str, &CostRecord)> {
        self.records.iter().map(|(k, r)| (k.as_str(), r))
    }

    /// Number of calibrated tasks.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been measured.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Lower into the correction layer the pipeline builder consumes.
    pub fn calibration(&self) -> CostCalibration {
        let mut cal = CostCalibration::new();
        for (key, r) in &self.records {
            cal.set_factor(key, r.factor());
        }
        cal
    }

    /// Serialize as an `hwdb`-style manifest.
    pub fn to_json(&self) -> String {
        let records = self
            .records
            .iter()
            .map(|(key, r)| {
                Json::obj(vec![
                    ("key", Json::Str(key.clone())),
                    ("symbol", Json::Str(r.symbol.clone())),
                    ("predicted_ns", Json::Num(r.predicted_ns as f64)),
                    ("measured_ns", Json::Num(r.measured_ns as f64)),
                    ("samples", Json::Num(r.samples as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(COST_DB_VERSION as f64)),
            ("generated_by", Json::Str("courier tune".into())),
            ("records", Json::Arr(records)),
        ])
        .to_string_pretty()
    }

    /// Parse a manifest produced by [`Self::to_json`].
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let version = v.req("version")?.as_u64()? as u32;
        if version != COST_DB_VERSION {
            return Err(crate::CourierError::Json(format!(
                "unsupported cost-db version {version}"
            )));
        }
        let mut db = Self::new();
        for r in v.req("records")?.as_arr()? {
            let key = r.req("key")?.as_str()?.to_string();
            db.records.insert(
                key,
                CostRecord {
                    symbol: r.req("symbol")?.as_str()?.to_string(),
                    predicted_ns: r.req("predicted_ns")?.as_u64()?,
                    measured_ns: r.req("measured_ns")?.as_u64()?,
                    samples: r.req("samples")?.as_u64()?.max(1),
                },
            );
        }
        Ok(db)
    }

    /// Write the manifest to disk atomically (temp file + rename): a
    /// concurrent reader — e.g. a cold `Server::open` loading the same
    /// manifest while a retune saves — sees either the old or the new
    /// file, never a torn write.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a manifest from disk; a missing file is an empty database
    /// (first tune run on a fresh checkout).
    pub fn load_or_default(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::new());
        }
        Self::parse(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::TempDir;

    #[test]
    fn record_keeps_a_running_mean() {
        let mut db = CalibratedCostDb::new();
        db.record("cv::x@8x8", "cv::x", 100, 200);
        db.record("cv::x@8x8", "cv::x", 100, 400);
        let r = db.get("cv::x@8x8").unwrap();
        assert_eq!(r.samples, 2);
        assert_eq!(r.measured_ns, 300);
        assert!((r.factor() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn changed_static_prediction_restarts_the_record() {
        let mut db = CalibratedCostDb::new();
        db.record("cv::x@8x8", "cv::x", 100, 400);
        db.record("cv::x@8x8", "cv::x", 100, 400); // factor 4.0, 2 samples
        // hwdb re-synthesized: the static estimate doubled
        db.record("cv::x@8x8", "cv::x", 200, 400);
        let r = db.get("cv::x@8x8").unwrap();
        assert_eq!(r.samples, 1, "stale measurements must not survive a model change");
        assert_eq!(r.predicted_ns, 200);
        assert!((r.factor() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn traced_mean_jitter_does_not_restart_the_record() {
        // SW predictions are traced means that wobble a few percent
        // between runs — that must accumulate, not restart
        let mut db = CalibratedCostDb::new();
        db.record("cv::x@8x8", "cv::x", 100_000, 400_000);
        db.record("cv::x@8x8", "cv::x", 103_217, 400_000);
        db.record("cv::x@8x8", "cv::x", 96_900, 400_000);
        let r = db.get("cv::x@8x8").unwrap();
        assert_eq!(r.samples, 3, "in-band jitter must accumulate samples");
        assert_eq!(r.predicted_ns, 100_000, "the anchor prediction stays put");
    }

    #[test]
    fn calibration_carries_factors() {
        let mut db = CalibratedCostDb::new();
        db.record("cv::x@8x8", "cv::x", 100, 250);
        let cal = db.calibration();
        assert_eq!(cal.apply_ns("cv::x@8x8", 1000), 2500);
        assert_eq!(cal.apply_ns("cv::other@8x8", 1000), 1000);
    }

    #[test]
    fn json_roundtrip_and_persistence() {
        let mut db = CalibratedCostDb::new();
        db.record("cv::a@4x4", "cv::a", 10, 20);
        db.record("cv::b@4x4x3", "cv::b", 30, 15);
        let back = CalibratedCostDb::parse(&db.to_json()).unwrap();
        assert_eq!(back, db);

        let tmp = TempDir::new("costdb").unwrap();
        let p = tmp.path().join("costs.json");
        db.save(&p).unwrap();
        assert_eq!(CalibratedCostDb::load_or_default(&p).unwrap(), db);
        // missing file -> empty db, not an error
        let fresh = CalibratedCostDb::load_or_default(&tmp.path().join("nope.json")).unwrap();
        assert!(fresh.is_empty());
    }

    #[test]
    fn bad_version_rejected() {
        let text = r#"{"version": 99, "records": []}"#;
        assert!(CalibratedCostDb::parse(text).is_err());
    }
}
