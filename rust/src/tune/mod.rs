//! `courier::tune` — the measurement-driven pipeline autotuner.
//!
//! The paper's Pipeline Generator balances stages from *predefined*
//! module costs; this subsystem closes the loop in three steps:
//!
//! 1. **calibrate** ([`calibrate::calibrate`]) — replay real frames
//!    through the untuned pipeline, compare measured per-stage latencies
//!    against the static model and the discrete-event simulator, and
//!    record per-task corrections into a persistent
//!    [`CalibratedCostDb`] (an `hwdb`-style JSON manifest) that feeds
//!    back into the builder through [`crate::hlo::CostCalibration`];
//! 2. **search** ([`search::search`]) — a budget-bounded hill-climb over
//!    partition boundaries, token counts, queue depths and
//!    software-stage fusion, scored by [`crate::pipeline::simulate`]
//!    over the calibrated task times, with the top-K candidates
//!    validated by real measured runs;
//! 3. **promote** — the winning plan is instantiated and can be handed
//!    to [`crate::serve::PlanCache::promote`], upgrading a serving key
//!    to the tuned plan without invalidating in-flight sessions.
//!
//! `courier tune --program <spec> --budget <n>` is the CLI entry point;
//! `docs/tuning.md` walks through the flow.

mod calibrate;
mod cost_db;
mod search;

pub use calibrate::{calibrate, CalibrationRun, StageCalibration};
pub use cost_db::{CalibratedCostDb, CostRecord, COST_DB_VERSION};
pub use search::{demote_modules, search, Candidate, ParetoPoint, SearchOutcome};

use std::sync::Arc;

use crate::app::{synth_frames, Program};
use crate::config::Config;
use crate::hwdb::HwDatabase;
use crate::image::Mat;
use crate::ir::Ir;
use crate::metrics::TunerMetrics;
use crate::pipeline::{instantiate, BuiltPipeline};
use crate::report::{ParetoRow, TuneReport, TuneRow};
use crate::runtime::Runtime;
use crate::swlib::Registry;
use crate::trace::{trace_program, CallGraph};
use crate::{CourierError, Result};

/// The tuner: borrows the same backend pieces the serving subsystem owns.
pub struct Tuner<'a> {
    db: &'a HwDatabase,
    rt: &'a Runtime,
    registry: &'a Registry,
    cfg: &'a Config,
    /// Modules excluded from hardware placement this run (the serving
    /// layer passes its quarantined set — see [`crate::serve::HealthTracker`]).
    quarantined: Vec<String>,
    /// Counters and timings for this tuner's lifetime.
    pub metrics: TunerMetrics,
}

/// Everything one `tune` run produced.
pub struct TuneOutcome {
    /// The rendered-ready report data.
    pub report: TuneReport,
    /// The instantiated winning pipeline (ready to serve or promote).
    pub winner: Arc<BuiltPipeline>,
    /// The winner's measured wall clock, ms/frame (the seed's
    /// calibration measurement when the seed won or the gate demoted).
    pub winner_measured_ms: f64,
    /// Recommended per-session ingress queue depth for the winner.
    pub queue_depth: usize,
    /// The cost database after this run's calibration samples.
    pub cost_db: CalibratedCostDb,
    /// The calibration pass over the untuned pipeline.
    pub calibration: CalibrationRun,
    /// True when the winner strictly beat the seed's score.
    pub improved: bool,
}

impl<'a> Tuner<'a> {
    /// A tuner over the given backend.
    pub fn new(
        db: &'a HwDatabase,
        rt: &'a Runtime,
        registry: &'a Registry,
        cfg: &'a Config,
    ) -> Self {
        Self { db, rt, registry, cfg, quarantined: Vec::new(), metrics: TunerMetrics::default() }
    }

    /// Exclude `modules` from hardware placement for this tuner's runs:
    /// their tasks are demoted to the software alternative before the
    /// search sees them, so a plan promoted mid-quarantine cannot place
    /// traffic the scheduler would immediately steer back to software.
    pub fn without_modules(mut self, modules: Vec<String>) -> Self {
        self.quarantined = modules;
        self
    }

    /// Calibrate → search → validate for `program`, starting from a fresh
    /// cost database.
    pub fn tune(&self, program: &Program) -> Result<TuneOutcome> {
        self.tune_with_db(program, CalibratedCostDb::new())
    }

    /// [`Self::tune`] seeded with an existing cost database (persisted
    /// calibrations from earlier runs keep sharpening the model).
    pub fn tune_with_db(
        &self,
        program: &Program,
        mut cost_db: CalibratedCostDb,
    ) -> Result<TuneOutcome> {
        program
            .validate()
            .map_err(|e| CourierError::Other(format!("program {}: {e}", program.name)))?;

        // -- trace -> IR -> seed build (exactly what serve would build
        //    today: cold opens consume the same cost database, so the
        //    baseline the tuner must beat is the *calibrated* build) -----
        let inputs = synth_frames(program, self.cfg.trace_frames.max(1));
        let trace = trace_program(program, &inputs)?;
        let mut ir = Ir::from_graph(&CallGraph::from_trace(&trace))?;
        ir.set_outputs_from(program)?;
        let pre_cal = (!cost_db.is_empty()).then(|| cost_db.calibration());
        let built_seed = Arc::new(crate::pipeline::build_calibrated(
            &ir,
            self.db,
            self.rt,
            self.registry,
            self.cfg,
            pre_cal.as_ref(),
        )?);
        built_seed.check_output_matches(program)?;

        // static estimates in flat task order (cut-independent): the cost
        // database anchors factors to these, never to calibrated values
        let static_ns: Vec<u64> =
            crate::pipeline::plan_pipeline(&ir, self.db, self.registry, self.cfg, None)?
                .stages
                .iter()
                .flat_map(|s| &s.tasks)
                .map(|t| t.est_ns)
                .collect();

        // -- calibrate on real frames --------------------------------------
        // Warm-up first: the process's very first pipeline run pays
        // one-time costs (page faults, thread spin-up, cold caches) that
        // would inflate the seed's measurement relative to the candidates
        // measured later — and thereby bias the promotion gate.
        let _ = built_seed.run(self.measure_stream(program))?;
        let calibration = calibrate(
            &built_seed,
            &ir,
            self.measure_stream(program),
            &static_ns,
            &mut cost_db,
            &self.metrics,
        )?;

        // -- re-price the seed plan: same cuts, freshest calibrated costs.
        // plan_pipeline applies the calibration to *static* estimates
        // (matching CalibratedCostDb::record, which pins the
        // first-recorded prediction), and the flattened task list is
        // cut-independent — so its calibrated estimates transplant onto
        // the seed's own cuts.  (Deliberately NOT the replanned cuts:
        // the point here is the seed *structure* priced at calibrated
        // costs.)
        let cal = cost_db.calibration();
        let tasks: Vec<_> =
            crate::pipeline::plan_pipeline(&ir, self.db, self.registry, self.cfg, Some(&cal))?
                .stages
                .into_iter()
                .flat_map(|s| s.tasks)
                .collect();
        // quarantined modules never reach the search as placement
        // options: their tasks demote to the software alternative here,
        // so every candidate (the seed structure included) prices and
        // places them on the CPU
        let tasks = demote_modules(&tasks, &self.quarantined);
        let mut seed_plan = built_seed.plan.clone();
        let mut task_idx = 0usize;
        for stage in &mut seed_plan.stages {
            for task in &mut stage.tasks {
                // kind + hw_cost ride along so a quarantine demotion
                // reaches the seed structure, not just its estimates
                task.est_ns = tasks[task_idx].est_ns;
                task.kind = tasks[task_idx].kind.clone();
                task.hw_cost = tasks[task_idx].hw_cost.clone();
                task_idx += 1;
            }
        }

        // -- search ---------------------------------------------------------
        let outcome = search(&seed_plan, &tasks, self.cfg, &self.metrics);

        // -- validate the top-K by measured runs ----------------------------
        // Validation runs are timed directly and NOT folded into the cost
        // database: candidate tasks carry already-calibrated estimates, so
        // recording against them would overwrite `predicted_ns` with the
        // calibrated value and collapse every stored factor toward 1.0 —
        // the persisted corrections would silently evaporate.
        // Queue-depth ladder entries (penalty > 0) reuse the incumbent's
        // plan byte-for-byte — measuring one would burn a top-K slot on a
        // run that teaches nothing, so only penalty-free candidates rank.
        // (Those are all distinct plans already: the search's seen-set
        // scores each (cuts, tokens) configuration at most once.)
        // Candidates whose fabric footprint exceeds `[serve]
        // fabric_area_luts` never rank: promotion is the latency-optimal
        // *in-budget* Pareto point.  (The seed passed the builder's
        // budget check and non-demotion candidates keep its placement,
        // so the gate only ever bites plans that grew the footprint.)
        let budget_luts = self.cfg.serve.fabric_area_luts as u64;
        let mut ranked: Vec<usize> = (0..outcome.candidates.len())
            .filter(|&i| {
                outcome.candidates[i].penalty_ns == 0
                    && outcome.candidates[i].plan.fabric_area_luts() <= budget_luts
            })
            .collect();
        ranked.sort_by_key(|&i| outcome.candidates[i].score());
        ranked.truncate(self.cfg.tune.top_k.max(1));
        let seed_measured_ms = calibration.wall_ms_per_frame();
        let mut measured: Vec<(String, f64)> = Vec::new();
        let mut validated: Vec<(usize, f64, Option<Arc<BuiltPipeline>>)> = Vec::new();
        for &i in &ranked {
            let cand = &outcome.candidates[i];
            if i == outcome.seed {
                // the calibration pass already measured the seed structure
                measured.push((cand.desc.clone(), seed_measured_ms));
                validated.push((i, seed_measured_ms, None));
                continue;
            }
            let built =
                Arc::new(instantiate(&cand.plan, self.db.dir(), self.rt, self.registry)?);
            let ms = self.measured_run(&built, program)?;
            measured.push((cand.desc.clone(), ms));
            validated.push((i, ms, Some(built)));
        }

        // -- pick the winner: sim ranks, measurement vetoes ------------------
        // Walk the validated candidates in score order and take the first
        // whose score beats the seed's AND whose measured run is not
        // clearly slower than the seed's (10% band absorbs scheduler
        // noise).  A vetoed sim-winner therefore falls back to the next
        // *validated* runner-up, not straight to the seed.  Score order is
        // makespan-first, so any selected winner's simulated makespan is
        // <= the seed's by construction; with no eligible candidate the
        // seed itself wins.
        let mut winner_idx = outcome.seed;
        let mut winner_built: Option<Arc<BuiltPipeline>> = None;
        let mut winner_sel_ms = seed_measured_ms;
        for (i, ms, built) in &validated {
            if *i == outcome.seed {
                continue;
            }
            let c = &outcome.candidates[*i];
            if c.score() < outcome.seed().score() && *ms <= seed_measured_ms * 1.10 {
                winner_idx = *i;
                winner_built = built.clone();
                winner_sel_ms = *ms;
                break;
            }
        }

        // -- assemble -------------------------------------------------------
        let winner_cand = &outcome.candidates[winner_idx];
        let winner = match winner_built {
            Some(b) => b,
            // the seed won: reuse the pipeline that is already
            // instantiated and calibration-validated — its plan differs
            // from winner_cand.plan only in the est_ns display values,
            // not in cuts or tokens
            None if winner_idx == outcome.seed => built_seed.clone(),
            // the selection loop only picks the seed (above) or a
            // validated candidate, and every validated non-seed entry
            // carries its instantiated pipeline
            None => unreachable!("non-seed winner must come from a validated candidate"),
        };
        let improved = winner_idx != outcome.seed;

        let rows = outcome
            .candidates
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mut verdict = if i == winner_idx && i == outcome.seed {
                    "seed winner".to_string()
                } else if i == winner_idx {
                    "winner".to_string()
                } else if i == outcome.seed {
                    "seed".to_string()
                } else {
                    "rejected".to_string()
                };
                if validated.iter().any(|(j, _, _)| *j == i) {
                    verdict.push_str(" validated");
                }
                TuneRow {
                    desc: c.desc.clone(),
                    sim_makespan_ms: c.sim.makespan_ns as f64 / 1e6,
                    sim_interval_ms: c.sim.frame_interval_ns as f64 / 1e6,
                    tokens: c.plan.tokens,
                    queue_depth: c.queue_depth,
                    verdict,
                }
            })
            .collect();

        let pareto: Vec<ParetoRow> = outcome
            .frontier
            .iter()
            .map(|p| ParetoRow {
                desc: outcome.candidates[p.candidate].desc.clone(),
                latency_ms: p.latency_ns as f64 / 1e6,
                area_luts: p.area_luts,
                power_mw: p.power_mw,
                promoted: p.candidate == winner_idx,
            })
            .collect();

        let report = TuneReport {
            program: program.name.clone(),
            budget: self.cfg.tune.budget,
            // simulator evaluations only: queue-depth ladder rows reuse
            // the incumbent's sim and are budget-exempt, so this number
            // never exceeds the stated budget
            evaluated: outcome.candidates.iter().filter(|c| c.penalty_ns == 0).count(),
            calibration_entries: cost_db.len(),
            calibration_factor: calibration.overall_factor(),
            seed_ms: outcome.seed().sim.makespan_ns as f64 / 1e6,
            winner_ms: winner_cand.sim.makespan_ns as f64 / 1e6,
            rows,
            measured,
            fabric_budget_luts: self.cfg.serve.fabric_area_luts,
            pareto,
        };
        let queue_depth = winner_cand.queue_depth;
        let winner_measured_ms = winner_sel_ms;

        Ok(TuneOutcome {
            report,
            winner,
            winner_measured_ms,
            queue_depth,
            cost_db,
            calibration,
            improved,
        })
    }

    /// A measurement stream for `program` (single-external-input flows —
    /// linear chains and DAGs alike).
    fn measure_stream(&self, program: &Program) -> Vec<Mat> {
        synth_frames(program, self.cfg.tune.measure_frames.max(1))
            .into_iter()
            .map(|mut v| v.remove(0))
            .collect()
    }

    /// Time one real run of `built`, ms/frame (validation only — nothing
    /// is recorded into the cost database; see the comment at the
    /// validation loop).
    fn measured_run(&self, built: &BuiltPipeline, program: &Program) -> Result<f64> {
        let frames = self.measure_stream(program);
        let n = frames.len().max(1) as u64;
        let t0 = std::time::Instant::now();
        let (_, stats) = built.run(frames)?;
        self.metrics.measure_time.record(t0.elapsed());
        self.metrics.measured_runs.inc();
        Ok(stats.wall_ns as f64 / n as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::corner_harris_demo;
    use crate::util::testing::TempDir;

    fn hermetic() -> (TempDir, Config) {
        let tmp = crate::util::testing::empty_hwdb_dir("tune").unwrap();
        let mut cfg = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };
        cfg.tune.budget = 24;
        cfg.tune.sim_frames = 16;
        cfg.tune.measure_frames = 2;
        (tmp, cfg)
    }

    #[test]
    fn tune_produces_report_and_never_regresses() {
        let (_tmp, cfg) = hermetic();
        let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        let registry = Registry::standard();
        let tuner = Tuner::new(&db, &rt, &registry, &cfg);
        let out = tuner.tune(&corner_harris_demo(24, 32)).unwrap();

        assert!(out.report.evaluated > 1, "search must explore candidates");
        assert!(out.report.evaluated <= cfg.tune.budget, "reported evals must respect budget");
        assert!(
            out.report.winner_ms <= out.report.seed_ms,
            "winner {} ms worse than seed {} ms",
            out.report.winner_ms,
            out.report.seed_ms
        );
        assert!(
            out.report.rows.iter().any(|r| r.verdict.starts_with("rejected")),
            "at least one candidate must be rejected"
        );
        assert!(!out.cost_db.is_empty(), "calibration must record tasks");
        assert!(!out.report.measured.is_empty(), "top-K must be measured");
        // all-sw run: every candidate has zero footprint, so the frontier
        // collapses to the single best-latency point
        assert_eq!(out.report.pareto.len(), 1, "{:?}", out.report.pareto);
        assert_eq!(out.report.pareto[0].area_luts, 0);
        assert_eq!(out.report.fabric_budget_luts, 53_200);
        assert!(out.report.pareto.iter().filter(|p| p.promoted).count() <= 1);
        assert!(crate::report::render_pareto(&out.report).contains("PARETO:"));
        // metrics count every candidate (including budget-exempt ladder
        // rows); the report counts simulator evaluations only
        assert!(tuner.metrics.candidates.get() >= out.report.evaluated as u64);

        // the winner serves frames correctly
        let frame = crate::image::synth::noise_rgb(24, 32, 3);
        let got = out.winner.process_one(frame.clone()).unwrap();
        let interp = crate::app::Interpreter::new(
            corner_harris_demo(24, 32),
            std::sync::Arc::new(crate::app::RegistryDispatch::standard()),
        );
        let want = interp.run(&[frame]).unwrap().remove(0);
        assert!(got.quantized_close(&want, 1.0, 1e-3), "tuned pipeline diverges");
    }

    #[test]
    fn tune_with_existing_db_accumulates_samples() {
        let (_tmp, cfg) = hermetic();
        let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        let registry = Registry::standard();
        let tuner = Tuner::new(&db, &rt, &registry, &cfg);
        let prog = corner_harris_demo(16, 16);
        let first = tuner.tune(&prog).unwrap();
        let second = tuner.tune_with_db(&prog, first.cost_db.clone()).unwrap();
        let key = "cv::cornerHarris@16x16#sw";
        assert!(
            second.cost_db.get(key).unwrap().samples > first.cost_db.get(key).unwrap().samples,
            "samples must accumulate across runs"
        );
    }
}
