//! Configuration-space search: partition boundaries, token-pool depth,
//! ingress queue depth and software-stage fusion, scored by the
//! discrete-event simulator over *calibrated* task times.
//!
//! The search is a bounded hill-climb seeded by a policy sweep:
//!
//! 1. **policy × tokens sweep** — every partition policy crossed with a
//!    small token-count ladder;
//! 2. **boundary hill-climb** — from the incumbent, move one interior
//!    stage boundary left/right one task at a time while it improves;
//! 3. **software-stage fusion** — merge adjacent all-CPU stages (helps
//!    when the plan has more stages than workers);
//! 4. **intra-frame band ladder** — shard software-stage interiors into
//!    row bands across otherwise-idle workers (tokens overlap *across*
//!    frames; bands split *within* one — the simulator prices the halo
//!    recompute, so banding only wins when idle capacity really exists);
//! 5. **placement demotion** — each hardware task with a software
//!    alternative is flipped to sw one at a time, trading latency
//!    against freed fabric area and power;
//! 6. **queue-depth ladder** — deeper ingress queues cost tail latency
//!    and win nothing once the token pool is covered, so depth is scored
//!    with an explicit latency penalty.
//!
//! Candidates are compared lexicographically: simulated makespan first,
//! then the queue-latency penalty, then smaller token pools and fewer
//! stages.  The seed plan is always candidate #0, so the winner's
//! simulated makespan can never exceed the untuned plan's.
//!
//! Besides the single winner, the search keeps the **Pareto frontier**
//! over (latency, area, power) — the tuner promotes the latency-optimal
//! point that fits the configured fabric area budget, which is the
//! winner whenever the winner fits.

use crate::config::Config;
use crate::metrics::TunerMetrics;
use crate::pipeline::{
    partition, simulate_with_model, SimModel, SimResult, StagePlan, StageSpec, TaskKind, TaskSpec,
};

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// The full stage plan (carries threads + tokens).
    pub plan: StagePlan,
    /// Recommended per-session ingress queue depth.
    pub queue_depth: usize,
    /// Human label for the TUNE report.
    pub desc: String,
    /// Simulator verdict.
    pub sim: SimResult,
    /// Queue-latency penalty, ns (non-zero only for deep-queue variants).
    pub penalty_ns: u64,
}

impl Candidate {
    /// Lexicographic comparison key (lower is better).
    pub fn score(&self) -> (u64, u64, usize, usize) {
        (self.sim.makespan_ns, self.penalty_ns, self.plan.tokens, self.plan.stages.len())
    }
}

/// One point on the latency × area × power Pareto frontier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParetoPoint {
    /// Index into [`SearchOutcome::candidates`].
    pub candidate: usize,
    /// Simulated makespan plus the queue-latency penalty, ns.
    pub latency_ns: u64,
    /// Fabric footprint of the plan's distinct hardware modules, LUTs.
    pub area_luts: u64,
    /// Fabric power of the plan's distinct hardware modules, mW.
    pub power_mw: u64,
}

/// The non-dominated subset of the scored candidates over
/// (latency, area, power), sorted by latency.  One representative is
/// kept per distinct objective triple (the earliest-scored candidate).
fn pareto_frontier(candidates: &[Candidate]) -> Vec<ParetoPoint> {
    let pts: Vec<ParetoPoint> = candidates
        .iter()
        .enumerate()
        .map(|(i, c)| ParetoPoint {
            candidate: i,
            latency_ns: c.sim.makespan_ns + c.penalty_ns,
            area_luts: c.plan.fabric_area_luts(),
            power_mw: c.plan.fabric_power_mw(),
        })
        .collect();
    let dominates = |a: &ParetoPoint, b: &ParetoPoint| {
        a.latency_ns <= b.latency_ns
            && a.area_luts <= b.area_luts
            && a.power_mw <= b.power_mw
            && (a.latency_ns < b.latency_ns
                || a.area_luts < b.area_luts
                || a.power_mw < b.power_mw)
    };
    let triple = |p: &ParetoPoint| (p.latency_ns, p.area_luts, p.power_mw);
    let mut out: Vec<ParetoPoint> = Vec::new();
    for p in &pts {
        if pts.iter().any(|q| dominates(q, p)) {
            continue;
        }
        // one representative per objective triple: the best-scored
        // candidate holding it (so the winner represents its own point)
        match out.iter_mut().find(|q| triple(q) == triple(p)) {
            Some(q) => {
                if candidates[p.candidate].score() < candidates[q.candidate].score() {
                    *q = p.clone();
                }
            }
            None => out.push(p.clone()),
        }
    }
    out.sort_by_key(triple);
    out
}

/// The search deliverable: every scored candidate plus seed/winner
/// indices into the list.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Candidates in evaluation order (index 0 is always the seed).
    pub candidates: Vec<Candidate>,
    /// Index of the untuned seed configuration.
    pub seed: usize,
    /// Index of the best configuration found.
    pub winner: usize,
    /// The latency × area × power Pareto frontier over the candidates,
    /// sorted by latency.  Promotion picks the latency-optimal point
    /// whose area fits `[serve].fabric_area_luts`
    /// ([`Self::best_within_area`]).
    pub frontier: Vec<ParetoPoint>,
}

impl SearchOutcome {
    /// The winning candidate.
    pub fn winner(&self) -> &Candidate {
        &self.candidates[self.winner]
    }

    /// The seed candidate.
    pub fn seed(&self) -> &Candidate {
        &self.candidates[self.seed]
    }

    /// The latency-optimal frontier point whose fabric footprint fits
    /// `budget_luts`.  `None` only when every point is over budget (an
    /// all-software plan has zero area, so any search seeded from one —
    /// or holding a demotion candidate — always yields a fit).
    pub fn best_within_area(&self, budget_luts: u64) -> Option<&ParetoPoint> {
        self.frontier.iter().find(|p| p.area_luts <= budget_luts)
    }
}

/// Assemble a plan from contiguous task groups (head/tail serial, middle
/// parallel — the paper's filter modes).  `edges` is the seed plan's
/// dataflow edge set: it is cut-independent (step granularity) and rides
/// along unchanged so every candidate stays DAG-wired.  `outputs` is the
/// seed's declared terminal set and rides along the same way — a tuner
/// move can regroup or demote tasks but never orphan a declared output.
fn plan_from_groups(
    program: &str,
    tasks: &[TaskSpec],
    edges: &[crate::pipeline::PlanEdge],
    outputs: &[usize],
    groups: &[std::ops::Range<usize>],
    threads: usize,
    tokens: usize,
    bands: usize,
) -> StagePlan {
    let n = groups.len();
    StagePlan {
        program: program.to_string(),
        threads,
        tokens,
        bands: bands.max(1),
        edges: edges.to_vec(),
        outputs: outputs.to_vec(),
        stages: groups
            .iter()
            .enumerate()
            .map(|(idx, r)| StageSpec {
                index: idx,
                tasks: tasks[r.clone()].to_vec(),
                serial: idx == 0 || idx == n - 1,
            })
            .collect(),
    }
}

/// Hashable identity of a configuration: stage end-cuts + token count +
/// band count (the search must never spend budget re-simulating a layout
/// it has already scored — the hill-climb would otherwise re-evaluate
/// the reverse of every accepted move).
fn config_sig(
    groups: &[std::ops::Range<usize>],
    tokens: usize,
    bands: usize,
) -> (Vec<usize>, usize, usize) {
    (groups.iter().map(|r| r.end).collect(), tokens, bands.max(1))
}

/// Recover the contiguous group ranges of a plan.
fn groups_of(plan: &StagePlan) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::with_capacity(plan.stages.len());
    let mut start = 0usize;
    for s in &plan.stages {
        out.push(start..start + s.tasks.len());
        start += s.tasks.len();
    }
    out
}

struct Evaluator<'a> {
    cfg: &'a Config,
    metrics: &'a TunerMetrics,
    remaining: usize,
    /// Sim-model knobs from `[tune]` (fusion link saving, band halo).
    model: SimModel,
}

impl Evaluator<'_> {
    fn eval(
        &mut self,
        plan: StagePlan,
        queue_depth: usize,
        penalty_ns: u64,
        desc: String,
    ) -> Option<Candidate> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let sim = self.metrics.sim_time.time(|| {
            simulate_with_model(
                &plan,
                self.cfg.tune.sim_frames.max(1) as u64,
                plan.threads.max(1),
                plan.tokens.max(1),
                &self.model,
            )
        });
        self.metrics.candidates.inc();
        Some(Candidate { plan, queue_depth, desc, sim, penalty_ns })
    }
}

/// Flip every hardware task placed on one of `modules` to its software
/// alternative (tasks without one stay placed — a module only the
/// fabric can serve has nowhere to demote to).  The serving layer's
/// health tracker feeds this: quarantined modules must not be offered
/// to the search as placement options, because a plan promoted
/// mid-quarantine would have its fabric traffic steered straight back
/// to software.
pub fn demote_modules(tasks: &[TaskSpec], modules: &[String]) -> Vec<TaskSpec> {
    tasks
        .iter()
        .map(|t| {
            let on_quarantined = match &t.kind {
                TaskKind::Hw { module, .. } => modules.contains(module),
                TaskKind::Sw => false,
            };
            match (&t.hw_cost, on_quarantined) {
                (Some(hc), true) if hc.sw_alt_ns > 0 => TaskSpec {
                    kind: TaskKind::Sw,
                    est_ns: hc.sw_alt_ns,
                    hw_cost: None,
                    scalars: Vec::new(),
                    ..t.clone()
                },
                _ => t.clone(),
            }
        })
        .collect()
}

/// Search the configuration space around `seed_plan` over calibrated task
/// times.  `tasks` must be the flattened task list of the seed plan (the
/// estimates inside are the calibrated ones the caller prepared).
pub fn search(
    seed_plan: &StagePlan,
    tasks: &[TaskSpec],
    cfg: &Config,
    metrics: &TunerMetrics,
) -> SearchOutcome {
    let times: Vec<u64> = tasks.iter().map(|t| t.est_ns).collect();
    let threads = seed_plan.threads.max(1);
    let base_depth = |tokens: usize| tokens.max(2);
    let mut ev = Evaluator {
        cfg,
        metrics,
        remaining: cfg.tune.budget.max(1),
        model: SimModel::from_tune(&cfg.tune),
    };
    let mut seen: std::collections::HashSet<(Vec<usize>, usize, usize)> =
        std::collections::HashSet::new();
    seen.insert(config_sig(&groups_of(seed_plan), seed_plan.tokens, seed_plan.bands));

    // The dataflow edge set rides along every candidate unchanged; moves
    // are additionally *checked* against it at task granularity so the
    // search can never propose a DAG-illegal cut (contiguity over the
    // topological task order makes legality automatic, but the guard
    // turns "automatic" into "verified").
    let edges = seed_plan.edges.clone();
    let outputs = seed_plan.outputs.clone();
    let task_of_step = |step: usize| tasks.iter().position(|t| t.covers.contains(&step));
    let task_edges: Vec<(usize, usize)> = seed_plan
        .effective_edges()
        .iter()
        .filter_map(|(p, c)| match p {
            Some(p) => match (task_of_step(*p), task_of_step(*c)) {
                (Some(a), Some(b)) if a != b => Some((a, b)),
                _ => None,
            },
            None => None,
        })
        .collect();
    let dag_legal = |groups: &[std::ops::Range<usize>]| -> bool {
        crate::pipeline::respects_dag(groups, &task_edges)
    };

    let mut candidates: Vec<Candidate> = Vec::new();
    let mut push = |cands: &mut Vec<Candidate>, c: Option<Candidate>| -> Option<usize> {
        c.map(|c| {
            cands.push(c);
            cands.len() - 1
        })
    };

    // -- 0) the untuned seed (always present, always scored first) ---------
    let seed_idx = push(
        &mut candidates,
        ev.eval(
            seed_plan.clone(),
            base_depth(seed_plan.tokens),
            0,
            format!(
                "seed policy={} tokens={} stages={}",
                cfg.policy.as_str(),
                seed_plan.tokens,
                seed_plan.stages.len()
            ),
        ),
    )
    .expect("budget >= 1 guarantees the seed is scored");
    let mut best = seed_idx;

    let better = |a: &Candidate, b: &Candidate| a.score() < b.score();
    let mut consider = |cands: &mut Vec<Candidate>, best: &mut usize, idx: Option<usize>| {
        if let Some(i) = idx {
            if better(&cands[i], &cands[*best]) {
                metrics.accepted.inc();
                *best = i;
            } else {
                metrics.rejected.inc();
            }
        }
    };

    // -- 1) policy x token sweep -------------------------------------------
    let mut token_ladder: Vec<usize> = [1usize, 2, 4, 8, 16]
        .into_iter()
        .filter(|&t| t <= cfg.tune.max_tokens.max(1))
        .chain(std::iter::once(seed_plan.tokens.max(1)))
        .collect();
    token_ladder.sort_unstable();
    token_ladder.dedup();

    for policy in [
        crate::config::PartitionPolicy::Paper,
        crate::config::PartitionPolicy::Optimal,
        crate::config::PartitionPolicy::PerFunction,
        crate::config::PartitionPolicy::Single,
    ] {
        let groups = partition(&times, threads, policy);
        if groups.is_empty() || !dag_legal(&groups) {
            continue;
        }
        for &tokens in &token_ladder {
            // the seen-set skips byte-identical repeats (the seed itself,
            // and policies that converge on the same cuts); the seed's
            // cuts came from *uncalibrated* estimates, so a repartition
            // under its own policy over calibrated times is a genuinely
            // new configuration and is scored like any other
            if !seen.insert(config_sig(&groups, tokens, seed_plan.bands)) {
                continue;
            }
            let plan = plan_from_groups(
                &seed_plan.program,
                tasks,
                &edges,
                &outputs,
                &groups,
                threads,
                tokens,
                seed_plan.bands,
            );
            let idx = push(
                &mut candidates,
                ev.eval(
                    plan,
                    base_depth(tokens),
                    0,
                    format!("policy={} tokens={tokens}", policy.as_str()),
                ),
            );
            consider(&mut candidates, &mut best, idx);
        }
    }

    // -- 2) boundary hill-climb around the incumbent -----------------------
    loop {
        let incumbent = candidates[best].clone();
        let groups = groups_of(&incumbent.plan);
        let mut moved = false;
        for b in 1..groups.len() {
            let cut = groups[b].start;
            for (delta, dir) in [(-1isize, "left"), (1, "right")] {
                let new_cut = cut.wrapping_add_signed(delta);
                // both neighbouring stages must stay non-empty
                if new_cut <= groups[b - 1].start || new_cut >= groups[b].end {
                    continue;
                }
                let mut shifted = groups.clone();
                shifted[b - 1] = shifted[b - 1].start..new_cut;
                shifted[b] = new_cut..shifted[b].end;
                if !dag_legal(&shifted) {
                    continue; // never propose a DAG-illegal boundary move
                }
                if !seen.insert(config_sig(&shifted, incumbent.plan.tokens, incumbent.plan.bands))
                {
                    continue; // already scored (e.g. the reverse of an accepted move)
                }
                let plan = plan_from_groups(
                    &incumbent.plan.program,
                    tasks,
                    &edges,
                    &outputs,
                    &shifted,
                    threads,
                    incumbent.plan.tokens,
                    incumbent.plan.bands,
                );
                let idx = push(
                    &mut candidates,
                    ev.eval(
                        plan,
                        incumbent.queue_depth,
                        0,
                        format!("shift cut#{b} {dir} (tokens={})", incumbent.plan.tokens),
                    ),
                );
                let before = best;
                consider(&mut candidates, &mut best, idx);
                moved |= best != before;
            }
        }
        if !moved || ev.remaining == 0 {
            break;
        }
    }

    // -- 3) software-stage fusion ------------------------------------------
    // merging adjacent all-CPU stages shrinks the stage count AND can
    // enable kernel fusion: chained single-consumer SW tasks that land in
    // one stage bind as a composed kernel at deploy time, which the
    // simulator credits (`StageSpec::fusion_credit_ns`) — so
    // fusion-enabling merges win on merit, not by special-casing
    {
        let incumbent = candidates[best].clone();
        let groups = groups_of(&incumbent.plan);
        let before_edges = incumbent.plan.effective_edges();
        for b in 1..groups.len() {
            let (lo, hi) = (&incumbent.plan.stages[b - 1], &incumbent.plan.stages[b]);
            if lo.has_hw() || hi.has_hw() {
                continue; // fusing across a fabric module changes placement
            }
            let mut fused = groups.clone();
            let merged = fused[b - 1].start..fused[b].end;
            fused.splice(b - 1..=b, [merged]);
            if !dag_legal(&fused) {
                continue;
            }
            if !seen.insert(config_sig(&fused, incumbent.plan.tokens, incumbent.plan.bands)) {
                continue;
            }
            let plan = plan_from_groups(
                &incumbent.plan.program,
                tasks,
                &edges,
                &outputs,
                &fused,
                threads,
                incumbent.plan.tokens,
                incumbent.plan.bands,
            );
            // report only the links the merge NEWLY enables (the cross-cut
            // ones), not links each pre-merge stage already carried
            let links = plan.stages[b - 1]
                .fusable_links(&plan.effective_edges())
                .saturating_sub(lo.fusable_links(&before_edges))
                .saturating_sub(hi.fusable_links(&before_edges));
            let desc = if links > 0 {
                format!("fuse sw stages {}+{} (enables {links} fused sw links)", b - 1, b)
            } else {
                format!("fuse sw stages {}+{}", b - 1, b)
            };
            let idx = push(
                &mut candidates,
                ev.eval(plan, incumbent.queue_depth, 0, desc),
            );
            consider(&mut candidates, &mut best, idx);
        }
    }

    // -- 4) intra-frame band ladder on the incumbent -----------------------
    // bands shard a software stage's interior across otherwise-idle
    // workers; the simulator prices the per-band halo recompute
    // ([`crate::pipeline::plan::BAND_HALO_OVERHEAD`]), so banding wins
    // only when idle worker capacity really exists — it trades against
    // the token axis instead of stacking on top of it blindly
    {
        let incumbent = candidates[best].clone();
        let groups = groups_of(&incumbent.plan);
        for bands in [2usize, 4] {
            if bands > threads {
                break; // more bands than workers only adds halo overhead
            }
            if !seen.insert(config_sig(&groups, incumbent.plan.tokens, bands)) {
                continue;
            }
            let mut plan = incumbent.plan.clone();
            plan.bands = bands;
            let idx = push(
                &mut candidates,
                ev.eval(
                    plan,
                    incumbent.queue_depth,
                    0,
                    format!("bands={bands} (tokens={})", incumbent.plan.tokens),
                ),
            );
            consider(&mut candidates, &mut best, idx);
        }
    }

    // -- 5) placement demotion (hw → sw flips) -----------------------------
    // each hardware task whose cost record carries a software alternative
    // is flipped to sw placement one at a time: the flip trades latency
    // (the traced software time replaces compute + both DMA crossings)
    // against the module's freed area and power, populating the cheap end
    // of the Pareto frontier.  A flip can also WIN outright when a
    // module's DMA overhead exceeds its compute advantage — the simulator
    // decides, not a heuristic.  Flips never touch cuts, tokens or bands,
    // so each is a genuinely new configuration (the seen-set keys on the
    // task list's placement being fixed, which the flip breaks).
    {
        let incumbent = candidates[best].clone();
        let groups = groups_of(&incumbent.plan);
        let inc_tasks: Vec<TaskSpec> =
            incumbent.plan.stages.iter().flat_map(|s| s.tasks.iter().cloned()).collect();
        for (ti, task) in inc_tasks.iter().enumerate() {
            let Some(hc) = &task.hw_cost else { continue };
            if matches!(task.kind, TaskKind::Sw) || hc.sw_alt_ns == 0 {
                continue;
            }
            let mut flipped = inc_tasks.clone();
            flipped[ti] = TaskSpec {
                kind: TaskKind::Sw,
                est_ns: hc.sw_alt_ns,
                hw_cost: None,
                scalars: Vec::new(),
                ..flipped[ti].clone()
            };
            let plan = plan_from_groups(
                &incumbent.plan.program,
                &flipped,
                &edges,
                &outputs,
                &groups,
                threads,
                incumbent.plan.tokens,
                incumbent.plan.bands,
            );
            let idx = push(
                &mut candidates,
                ev.eval(
                    plan,
                    incumbent.queue_depth,
                    0,
                    format!(
                        "demote {} to sw (frees {} LUTs, {} mW)",
                        task.symbol, hc.area_luts, hc.power_mw
                    ),
                ),
            );
            consider(&mut candidates, &mut best, idx);
        }
    }

    // -- 6) queue-depth ladder on the incumbent ----------------------------
    {
        let incumbent = candidates[best].clone();
        let base = base_depth(incumbent.plan.tokens);
        for mult in [2usize, 4] {
            let depth = base * mult;
            // a deeper ingress queue cannot raise throughput once the
            // token pool is covered; it only queues frames longer — the
            // penalty prices that tail latency into the score.  The plan
            // is byte-identical and simulate() does not model the ingress
            // queue, so the incumbent's sim is reused instead of spending
            // budget on a duplicate run.
            let penalty = (depth - base) as u64 * incumbent.sim.frame_interval_ns;
            metrics.candidates.inc();
            candidates.push(Candidate {
                plan: incumbent.plan.clone(),
                queue_depth: depth,
                desc: format!("queue_depth={depth}"),
                sim: incumbent.sim.clone(),
                penalty_ns: penalty,
            });
            let idx = Some(candidates.len() - 1);
            consider(&mut candidates, &mut best, idx);
        }
    }

    let frontier = pareto_frontier(&candidates);
    SearchOutcome { candidates, seed: seed_idx, winner: best, frontier }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PartitionPolicy;
    use crate::pipeline::TaskKind;

    fn sw_tasks(times_ms: &[u64]) -> Vec<TaskSpec> {
        times_ms
            .iter()
            .enumerate()
            .map(|(i, &ms)| TaskSpec {
                covers: vec![i],
                symbol: format!("cv::f{i}"),
                kind: TaskKind::Sw,
                est_ns: ms * 1_000_000,
                hw_cost: None,
                scalars: Vec::new(),
            })
            .collect()
    }

    /// A 3-task chain whose middle task sits on the fabric: compute +
    /// DMA ≈ 7 ms against a 40 ms software alternative, 12k LUTs,
    /// 250 mW.
    fn hw_middle_tasks() -> Vec<TaskSpec> {
        let mut tasks = sw_tasks(&[10, 0, 8]);
        tasks[1] = TaskSpec {
            kind: TaskKind::Hw {
                module: "hls_mid".into(),
                artifact: "hls_mid.hlo.txt".into(),
            },
            est_ns: 5_000_000,
            hw_cost: Some(crate::pipeline::HwCost {
                area_luts: 12_000,
                power_mw: 250,
                xfer_in_ns: 1_000_000,
                xfer_out_ns: 1_000_000,
                sw_alt_ns: 40_000_000,
            }),
            ..tasks[1].clone()
        };
        tasks
    }

    fn seed_of(tasks: &[TaskSpec], threads: usize, tokens: usize, policy: PartitionPolicy) -> StagePlan {
        let times: Vec<u64> = tasks.iter().map(|t| t.est_ns).collect();
        let groups = partition(&times, threads, policy);
        plan_from_groups("t", tasks, &[], &[], &groups, threads, tokens, 1)
    }

    fn cfg_with(budget: usize) -> Config {
        let mut cfg = Config::default();
        cfg.tune.budget = budget;
        cfg.tune.sim_frames = 16;
        cfg
    }

    #[test]
    fn winner_never_worse_than_seed() {
        let tasks = sw_tasks(&[5, 40, 12, 30, 8]);
        let cfg = cfg_with(64);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let metrics = TunerMetrics::default();
        let out = search(&seed, &tasks, &cfg, &metrics);
        assert_eq!(out.seed, 0);
        assert!(
            out.winner().sim.makespan_ns <= out.seed().sim.makespan_ns,
            "winner {} > seed {}",
            out.winner().sim.makespan_ns,
            out.seed().sim.makespan_ns
        );
        assert!(out.candidates.len() > 1, "search must explore");
        assert!(metrics.candidates.get() as usize == out.candidates.len());
        assert!(metrics.rejected.get() > 0, "some candidate must lose");
    }

    #[test]
    fn budget_bounds_evaluations() {
        let tasks = sw_tasks(&[5, 40, 12, 30, 8, 3, 3, 3]);
        let cfg = cfg_with(5);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let metrics = TunerMetrics::default();
        let out = search(&seed, &tasks, &cfg, &metrics);
        // the queue-depth ladder (2 entries) reuses the incumbent's sim
        // without spending budget, so the bound is budget + 2
        assert!(out.candidates.len() <= 5 + 2, "{} > budget + ladder", out.candidates.len());
    }

    #[test]
    fn budget_of_one_scores_only_the_seed() {
        let tasks = sw_tasks(&[10, 10]);
        let cfg = cfg_with(1);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        // seed + the budget-free queue-depth ladder over it
        assert_eq!(out.candidates.len(), 3);
        assert_eq!(out.winner, out.seed, "ladder variants carry a penalty and cannot win");
    }

    #[test]
    fn deep_queues_are_penalized_not_preferred() {
        let tasks = sw_tasks(&[10, 10, 10]);
        let cfg = cfg_with(64);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        let winner = out.winner();
        // ladder variants exist in the candidate list but never win
        assert!(out.candidates.iter().any(|c| c.penalty_ns > 0));
        assert_eq!(winner.penalty_ns, 0);
        assert_eq!(winner.queue_depth, winner.plan.tokens.max(2));
    }

    #[test]
    fn dag_seed_candidates_are_all_dag_legal() {
        // a harris-shaped DAG seed: 0 -> {1, 2} -> 3 -> 4; every candidate
        // the search scores must keep a legal wiring
        let tasks = sw_tasks(&[5, 40, 30, 25, 8]);
        let edges: Vec<crate::pipeline::PlanEdge> = vec![
            (None, 0),
            (Some(0), 1),
            (Some(0), 2),
            (Some(1), 3),
            (Some(2), 3),
            (Some(3), 4),
        ];
        let times: Vec<u64> = tasks.iter().map(|t| t.est_ns).collect();
        let groups = partition(&times, 2, PartitionPolicy::Paper);
        let seed = plan_from_groups("dag", &tasks, &edges, &[], &groups, 2, 4, 1);
        seed.validate_dag().unwrap();

        let cfg = cfg_with(64);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        assert!(out.candidates.len() > 1, "search must explore");
        for c in &out.candidates {
            c.plan.validate_dag().unwrap_or_else(|e| {
                panic!("search proposed a DAG-illegal candidate ({}): {e}", c.desc)
            });
            assert_eq!(c.plan.edges, edges, "edges must ride along unchanged");
        }
    }

    #[test]
    fn search_emits_fusion_enabling_partition_for_harris_chain() {
        // the CPU-only Harris chain shape (cvt → harris → normalize →
        // csa): the search must score at least one partition that
        // colocates chained SW tasks the seed keeps apart — i.e. a
        // candidate with strictly more fusable links than the seed —
        // because the simulator credits fused links
        let tasks = sw_tasks(&[12, 40, 8, 5]);
        let cfg = cfg_with(64);
        let seed = seed_of(&tasks, 2, 4, PartitionPolicy::Paper);
        let metrics = TunerMetrics::default();
        let out = search(&seed, &tasks, &cfg, &metrics);
        let links = |p: &StagePlan| -> usize {
            let e = p.effective_edges();
            p.stages.iter().map(|s| s.fusable_links(&e)).sum()
        };
        let seed_links = links(&out.seed().plan);
        assert!(
            out.candidates.iter().any(|c| links(&c.plan) > seed_links),
            "search must emit a fusion-enabling partition candidate \
             (seed has {seed_links} links)"
        );
    }

    #[test]
    fn single_stage_seed_still_searches_tokens() {
        let tasks = sw_tasks(&[25]);
        let cfg = cfg_with(32);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        assert!(out.candidates.len() > 1);
        // one task: no cut or token variant can beat the seed's makespan
        // — only the band ladder can (sharding the single stage's
        // interior), so the winner is at worst the seed and at best a
        // banded variant of it
        assert!(out.winner().sim.makespan_ns <= out.seed().sim.makespan_ns);
        assert_eq!(groups_of(&out.winner().plan), groups_of(&out.seed().plan));
    }

    #[test]
    fn demotion_populates_a_multi_point_pareto_frontier() {
        let tasks = hw_middle_tasks();
        let cfg = cfg_with(64);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());

        // a demotion candidate exists and its plan really is all-sw
        let demoted = out
            .candidates
            .iter()
            .find(|c| c.desc.starts_with("demote cv::f1"))
            .expect("hw task with a sw alternative must produce a demotion candidate");
        assert_eq!(demoted.plan.fabric_area_luts(), 0);
        assert!(demoted
            .plan
            .stages
            .iter()
            .flat_map(|s| &s.tasks)
            .all(|t| matches!(t.kind, TaskKind::Sw)));

        // the frontier holds (at least) the fast-but-large hw point and
        // the slow-but-free sw point — neither dominates the other
        assert!(out.frontier.len() >= 2, "frontier: {:?}", out.frontier);
        let hw_pt = out.frontier.iter().find(|p| p.area_luts == 12_000).expect("hw point");
        let sw_pt = out.frontier.iter().find(|p| p.area_luts == 0).expect("sw point");
        assert_eq!(hw_pt.power_mw, 250);
        assert!(hw_pt.latency_ns < sw_pt.latency_ns);

        // frontier is sorted by latency and genuinely non-dominated
        for w in out.frontier.windows(2) {
            assert!(w[0].latency_ns <= w[1].latency_ns);
            assert!(
                w[1].area_luts < w[0].area_luts || w[1].power_mw < w[0].power_mw,
                "a later frontier point must win on some axis: {:?}",
                out.frontier
            );
        }

        // promotion policy: latency-optimal within budget
        assert_eq!(
            out.best_within_area(53_200).unwrap().candidate,
            hw_pt.candidate,
            "a roomy budget takes the fast hw point"
        );
        assert_eq!(
            out.best_within_area(1_000).unwrap().candidate,
            sw_pt.candidate,
            "a tiny budget forces the all-sw point"
        );
    }

    #[test]
    fn winner_is_on_the_frontier_and_within_any_covering_budget() {
        let tasks = sw_tasks(&[5, 40, 12, 30, 8]);
        let cfg = cfg_with(64);
        let seed = seed_of(&tasks, cfg.threads, cfg.tokens, cfg.policy);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        // all-sw search: every plan has zero footprint, so the frontier
        // collapses to the single best latency — the winner
        assert_eq!(out.frontier.len(), 1);
        assert_eq!(out.frontier[0].candidate, out.winner);
        assert_eq!(out.best_within_area(0).unwrap().candidate, out.winner);
    }

    #[test]
    fn demote_modules_flips_only_quarantined_placements() {
        let tasks = hw_middle_tasks();
        let out = demote_modules(&tasks, &["hls_mid".to_string()]);
        assert!(matches!(out[1].kind, TaskKind::Sw), "quarantined module demotes");
        assert_eq!(out[1].est_ns, 40_000_000, "demotion prices the sw alternative");
        assert!(out[1].hw_cost.is_none());
        assert_eq!(out[0], tasks[0]);
        assert_eq!(out[2], tasks[2]);

        // an unrelated quarantine leaves the placement alone
        let kept = demote_modules(&tasks, &["other".to_string()]);
        assert_eq!(kept, tasks);

        // no software alternative: the task has nowhere to demote to
        let mut stuck = hw_middle_tasks();
        if let Some(hc) = &mut stuck[1].hw_cost {
            hc.sw_alt_ns = 0;
        }
        let out = demote_modules(&stuck, &["hls_mid".to_string()]);
        assert!(matches!(out[1].kind, TaskKind::Hw { .. }), "hw-only task stays placed");
    }

    #[test]
    fn band_ladder_wins_when_workers_idle() {
        // one dominant software stage with 4 workers and a token pool of
        // 1: the frame holds a single worker un-banded, so the bands axis
        // is the only way to use the idle capacity — the winner must be a
        // banded plan with a strictly better makespan
        let tasks = sw_tasks(&[40]);
        let cfg = cfg_with(32);
        let seed = seed_of(&tasks, 4, 1, PartitionPolicy::Single);
        let out = search(&seed, &tasks, &cfg, &TunerMetrics::default());
        let winner = out.winner();
        assert!(winner.plan.bands > 1, "winner must band: {}", winner.desc);
        assert!(
            winner.sim.makespan_ns < out.seed().sim.makespan_ns,
            "banded winner {} must beat the un-banded seed {}",
            winner.sim.makespan_ns,
            out.seed().sim.makespan_ns
        );
        // and the deduper must keep the ladder from re-scoring the seed
        assert!(
            out.candidates.iter().filter(|c| c.plan.bands == 1).count() >= 1,
            "the un-banded incumbent stays in the list"
        );
    }
}
