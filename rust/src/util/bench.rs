//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `harness = false` bench binaries use [`Bench`] for warm-up, repeated
//! measurement, and mean/p50/min reporting, plus table-style printing so
//! `cargo bench` output can be diffed against the paper's tables.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Case label.
    pub label: String,
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean wall time per iteration, ns.
    pub mean_ns: u64,
    /// Median wall time, ns.
    pub p50_ns: u64,
    /// Minimum wall time, ns.
    pub min_ns: u64,
}

impl Measurement {
    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns as f64 / 1e6
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 1, min_iters: 3, max_iters: 50, budget: Duration::from_secs(5) }
    }
}

impl Bench {
    /// Harness with a custom per-case budget.
    pub fn with_budget(budget: Duration) -> Self {
        Self { budget, ..Default::default() }
    }

    /// Quick harness for cheap cases.
    pub fn quick() -> Self {
        Self {
            warmup: 2,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(2),
        }
    }

    /// Measure `f`, printing and returning the measurement.
    pub fn run<T>(&self, label: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<u64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        samples.sort_unstable();
        let m = Measurement {
            label: label.to_string(),
            iters: samples.len(),
            mean_ns: samples.iter().sum::<u64>() / samples.len() as u64,
            p50_ns: samples[samples.len() / 2],
            min_ns: samples[0],
        };
        println!(
            "bench {:<44} mean {:>10.3} ms   p50 {:>10.3} ms   min {:>10.3} ms   ({} iters)",
            m.label,
            m.mean_ns as f64 / 1e6,
            m.p50_ns as f64 / 1e6,
            m.min_ns as f64 / 1e6,
            m.iters
        );
        m
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench {
            warmup: 1,
            min_iters: 3,
            max_iters: 5,
            budget: Duration::from_millis(50),
        };
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.iters >= 3);
        assert!(m.min_ns > 0);
        assert!(m.min_ns <= m.p50_ns && m.p50_ns <= m.mean_ns * 2);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 4,
            budget: Duration::from_secs(60),
        };
        let m = b.run("fast", || 1 + 1);
        assert!(m.iters <= 4);
    }
}
