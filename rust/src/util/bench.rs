//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `harness = false` bench binaries use [`Bench`] for warm-up, repeated
//! measurement, and mean/p50/min reporting, plus table-style printing so
//! `cargo bench` output can be diffed against the paper's tables.
//!
//! Every bench binary also serializes its measurements with
//! [`write_bench_json`] into a `BENCH_<name>.json` artifact at the repo
//! root, so the perf trajectory is machine-comparable across commits.
//! Setting `COURIER_BENCH_SMOKE=1` switches [`Bench::from_env`] (and the
//! binaries' workload sizes) to a seconds-long smoke budget for CI.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Case label.
    pub label: String,
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean wall time per iteration, ns.
    pub mean_ns: u64,
    /// Median wall time, ns.
    pub p50_ns: u64,
    /// Minimum wall time, ns.
    pub min_ns: u64,
}

impl Measurement {
    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns as f64 / 1e6
    }

    /// JSON form (for `BENCH_*.json` artifacts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns as f64)),
            ("p50_ns", Json::Num(self.p50_ns as f64)),
            ("min_ns", Json::Num(self.min_ns as f64)),
        ])
    }
}

/// True when `COURIER_BENCH_SMOKE=1`: bench binaries shrink workloads and
/// budgets to a CI-sized smoke run (the JSON artifact records the mode).
pub fn smoke() -> bool {
    std::env::var("COURIER_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Serialize a bench run into `BENCH_<name>.json` at the repo root (or
/// `$COURIER_BENCH_DIR` when set) and return the path written.
///
/// `extras` carries the bench's headline scalars (speed-ups, frame
/// intervals, pool hit rates, ...) so trajectory comparisons don't have
/// to re-derive them from the raw measurements.
pub fn write_bench_json(
    name: &str,
    measurements: &[Measurement],
    extras: &[(&str, f64)],
) -> std::io::Result<PathBuf> {
    let root = match std::env::var("COURIER_BENCH_DIR") {
        Ok(dir) => PathBuf::from(dir),
        // the crate lives in <repo>/rust: artifacts land at the repo root
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap_or_else(|| Path::new("."))
            .to_path_buf(),
    };
    write_bench_json_at(&root, name, measurements, extras)
}

/// [`write_bench_json`] into an explicit directory.
pub fn write_bench_json_at(
    root: &Path,
    name: &str,
    measurements: &[Measurement],
    extras: &[(&str, f64)],
) -> std::io::Result<PathBuf> {
    let mut members = vec![
        ("bench", Json::Str(name.to_string())),
        ("smoke", Json::Bool(smoke())),
        (
            "measurements",
            Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
        ),
    ];
    for &(k, v) in extras {
        members.push((k, Json::Num(v)));
    }
    let path = root.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, Json::obj(members).to_string_pretty())?;
    println!("wrote {}", path.display());
    Ok(path)
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 1, min_iters: 3, max_iters: 50, budget: Duration::from_secs(5) }
    }
}

impl Bench {
    /// Harness with a custom per-case budget.
    pub fn with_budget(budget: Duration) -> Self {
        Self { budget, ..Default::default() }
    }

    /// [`Bench::with_budget`], unless `COURIER_BENCH_SMOKE=1` caps the
    /// run at a few fast iterations.
    pub fn from_env(budget: Duration) -> Self {
        if smoke() {
            Self {
                warmup: 0,
                min_iters: 1,
                max_iters: 3,
                budget: Duration::from_millis(250),
            }
        } else {
            Self::with_budget(budget)
        }
    }

    /// Quick harness for cheap cases.
    pub fn quick() -> Self {
        Self {
            warmup: 2,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(2),
        }
    }

    /// Measure `f`, printing and returning the measurement.
    pub fn run<T>(&self, label: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<u64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        samples.sort_unstable();
        let m = Measurement {
            label: label.to_string(),
            iters: samples.len(),
            mean_ns: samples.iter().sum::<u64>() / samples.len() as u64,
            p50_ns: samples[samples.len() / 2],
            min_ns: samples[0],
        };
        println!(
            "bench {:<44} mean {:>10.3} ms   p50 {:>10.3} ms   min {:>10.3} ms   ({} iters)",
            m.label,
            m.mean_ns as f64 / 1e6,
            m.p50_ns as f64 / 1e6,
            m.min_ns as f64 / 1e6,
            m.iters
        );
        m
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench {
            warmup: 1,
            min_iters: 3,
            max_iters: 5,
            budget: Duration::from_millis(50),
        };
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.iters >= 3);
        assert!(m.min_ns > 0);
        assert!(m.min_ns <= m.p50_ns && m.p50_ns <= m.mean_ns * 2);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 4,
            budget: Duration::from_secs(60),
        };
        let m = b.run("fast", || 1 + 1);
        assert!(m.iters <= 4);
    }

    #[test]
    fn json_artifact_roundtrips() {
        let tmp = crate::util::testing::TempDir::new("bench-json").unwrap();
        let m = Measurement {
            label: "case".into(),
            iters: 5,
            mean_ns: 1_000,
            p50_ns: 900,
            min_ns: 800,
        };
        let path =
            write_bench_json_at(tmp.path(), "unit", &[m], &[("speedup", 2.5)]).unwrap();
        assert!(path.ends_with("BENCH_unit.json"));
        let parsed = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.req("bench").unwrap().as_str().unwrap(), "unit");
        assert_eq!(parsed.req("speedup").unwrap().as_f64().unwrap(), 2.5);
        let ms = parsed.req("measurements").unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].req("mean_ns").unwrap().as_u64().unwrap(), 1_000);
    }
}
