//! Minimal JSON: value model, parser, pretty-printer.
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms
//! beyond f64.  Object member order is preserved (important for
//! deterministic artifacts).  All Courier serialization (manifest, trace,
//! IR, plans) goes through this module.

use std::fmt::Write as _;

use crate::{CourierError, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (f64 carries integers exactly to 2^53)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (insertion-ordered)
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Member that must exist.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| CourierError::Json(format!("missing key {key:?}")))
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(CourierError::Json(format!("expected number, got {other:?}"))),
        }
    }

    /// As u64 (checked non-negative integral).
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(CourierError::Json(format!("expected unsigned integer, got {f}")));
        }
        Ok(f as u64)
    }

    /// As usize.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(CourierError::Json(format!("expected bool, got {other:?}"))),
        }
    }

    /// As str.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(CourierError::Json(format!("expected string, got {other:?}"))),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(CourierError::Json(format!("expected array, got {other:?}"))),
        }
    }

    /// Array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    /// Build an object.
    pub fn obj(members: Vec<(&str, Json)>) -> Json {
        Json::Obj(members.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// usize array value.
    pub fn from_usizes(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(members) => {
                if members.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> CourierError {
        CourierError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {s:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex digit"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // raw UTF-8 passthrough: re-decode multibyte sequences
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(members)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("name", Json::Str("hls_x".into())),
            ("sizes", Json::from_usizes(&[48, 64])),
            ("enabled", Json::Bool(false)),
            ("nested", Json::obj(vec![("k", Json::Num(1.5))])),
        ]);
        for s in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{0007}é".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(42.5).to_string_compact(), "42.5");
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn accessors_check_types() {
        let v = parse(r#"{"n": 3, "s": "x", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert!(v.req("s").unwrap().as_f64().is_err());
        assert!(v.req("missing").is_err());
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1]);
        assert!(Json::Num(1.5).as_u64().is_err());
        assert!(Json::Num(-1.0).as_u64().is_err());
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !p.exists() {
            return;
        }
        let v = parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        assert_eq!(v.req("version").unwrap().as_u64().unwrap(), 1);
        assert!(!v.req("modules").unwrap().as_arr().unwrap().is_empty());
    }
}
