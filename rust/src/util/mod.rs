//! In-crate substrates for an offline build: JSON, TOML-subset, RNG,
//! bench harness, property-testing helpers.
//!
//! The build environment vendors only the `xla` dependency closure, so the
//! serialization / randomness / benchmarking infrastructure other projects
//! pull from crates.io is implemented here (and unit-tested like any other
//! substrate).

pub mod bench;
pub mod json;
pub mod rng;
pub mod testing;
pub mod tomlmini;
