//! Deterministic PRNG (SplitMix64) — the workload-generation randomness.
//!
//! SplitMix64 passes BigCrush, is seedable, and is 6 lines long — exactly
//! what synthetic frames and property tests need.  Not cryptographic.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (same seed -> same stream, forever).
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform usize in [0, n) (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..5).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..5).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let c: Vec<u64> = (0..5).map({ let mut r = Rng::new(8); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(3);
        let mut buckets = [0usize; 8];
        for _ in 0..8000 {
            buckets[r.below(8)] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "{buckets:?}");
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
        }
    }
}
