//! Property-testing + temp-dir helpers (proptest/tempfile are unavailable
//! offline).
//!
//! [`forall`] runs a property over N seeded random cases and, on failure,
//! retries with simpler cases (halved sizes) to report a smaller
//! counterexample seed — a pragmatic subset of proptest's shrinking.

use super::rng::Rng;

/// Run `prop` over `cases` seeded inputs built by `gen`.  Panics with the
/// failing seed (and a smaller reproduction if one is found).
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property failed on case {case} (seed {seed:#x}): input = {input:?}");
        }
    }
}

/// Random `Vec<u64>` in [1, max_val) with len in [1, max_len].
pub fn vec_u64(rng: &mut Rng, max_len: usize, max_val: u64) -> Vec<u64> {
    let len = 1 + rng.below(max_len);
    (0..len).map(|_| 1 + rng.next_u64() % (max_val - 1)).collect()
}

/// A fresh [`TempDir`] holding an empty-but-valid hardware-database
/// manifest: every lookup misses, so pipelines place everything on the
/// CPU and no AOT artifact is required — the standard hermetic-test
/// setup (shared here so a manifest schema change edits one place).
pub fn empty_hwdb_dir(tag: &str) -> std::io::Result<TempDir> {
    let dir = TempDir::new(tag)?;
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"version": 1, "fabric_clock_mhz": 157.0, "modules": []}"#,
    )?;
    Ok(dir)
}

/// A self-deleting temporary directory (tempfile analogue).
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir.
    pub fn new(tag: &str) -> std::io::Result<Self> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "courier-{tag}-{}-{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_on_true_property() {
        forall(
            50,
            |rng| vec_u64(rng, 16, 1000),
            |v| v.iter().sum::<u64>() >= *v.iter().max().unwrap(),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_panics_on_false_property() {
        forall(50, |rng| rng.below(100), |&n| n < 50);
    }

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(p.join("x"), "y").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }
}
