//! Minimal TOML subset for `courier.toml`: `key = value` pairs with
//! string, integer, float and boolean values, `#` comments, and one level
//! of `[table]` headers.  A key inside `[serve]` is addressed as
//! `serve.key`; no nested tables or arrays — the config stays flat.

use std::collections::BTreeMap;

use crate::{CourierError, Result};

/// A parsed flat TOML document.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

/// A TOML scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl TomlDoc {
    /// Parse a TOML document (flat keys + one level of `[table]` headers).
    ///
    /// Duplicate keys and duplicate `[table]` headers are **errors**
    /// carrying the offending line number, matching real TOML: the old
    /// silent last-wins overwrite meant a config typo like two `[tune]`
    /// sections quietly dropped half the settings.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut first_line: BTreeMap<String, usize> = BTreeMap::new();
        let mut seen_tables: BTreeMap<String, usize> = BTreeMap::new();
        let mut prefix = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').map(str::trim).ok_or_else(|| {
                    CourierError::Config(format!("line {}: malformed table header", idx + 1))
                })?;
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                {
                    return Err(CourierError::Config(format!(
                        "line {}: bad table name {name:?}",
                        idx + 1
                    )));
                }
                if let Some(first) = seen_tables.insert(name.to_string(), idx + 1) {
                    return Err(CourierError::Config(format!(
                        "line {}: duplicate table [{name}] (first defined on line {first})",
                        idx + 1
                    )));
                }
                prefix = format!("{name}.");
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                CourierError::Config(format!("line {}: expected key = value", idx + 1))
            })?;
            let key = format!("{prefix}{}", k.trim());
            let val = parse_value(v.trim())
                .ok_or_else(|| CourierError::Config(format!("line {}: bad value {v:?}", idx + 1)))?;
            if let Some(first) = first_line.insert(key.clone(), idx + 1) {
                return Err(CourierError::Config(format!(
                    "line {}: duplicate key {key:?} (first set on line {first})",
                    idx + 1
                )));
            }
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    /// String value.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Integer value (as usize).
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        match self.values.get(key) {
            Some(TomlValue::Int(i)) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Float value (integers coerce, like real TOML readers do).
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    /// Whether a key exists.
    pub fn contains(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    /// All keys (for unknown-key warnings).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
        return Some(TomlValue::Str(v[1..v.len() - 1].to_string()));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_document() {
        let doc = TomlDoc::parse(
            "# comment\nthreads = 4\npolicy = \"optimal\"\ncpu_only = true\nratio = 1.5\n",
        )
        .unwrap();
        assert_eq!(doc.get_usize("threads"), Some(4));
        assert_eq!(doc.get_str("policy"), Some("optimal"));
        assert_eq!(doc.get_bool("cpu_only"), Some(true));
        assert!(doc.contains("ratio"));
        assert_eq!(doc.get_f64("ratio"), Some(1.5));
        assert_eq!(doc.get_f64("threads"), Some(4.0), "ints coerce to float");
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = TomlDoc::parse("path = \"a#b\" # real comment\n").unwrap();
        assert_eq!(doc.get_str("path"), Some("a#b"));
    }

    #[test]
    fn table_headers_prefix_keys() {
        let doc = TomlDoc::parse("threads = 2\n[serve]\nworkers = 4\nmax_sessions = 8\n").unwrap();
        assert_eq!(doc.get_usize("threads"), Some(2));
        assert_eq!(doc.get_usize("serve.workers"), Some(4));
        assert_eq!(doc.get_usize("serve.max_sessions"), Some(8));
        assert!(!doc.contains("workers"));
    }

    #[test]
    fn rejects_bad_tables_and_garbage() {
        assert!(TomlDoc::parse("[section]\n").is_ok());
        assert!(TomlDoc::parse("[bad name]\n").is_err());
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("[]\n").is_err());
        assert!(TomlDoc::parse("key value\n").is_err());
        assert!(TomlDoc::parse("key = @@\n").is_err());
    }

    #[test]
    fn duplicate_keys_error_with_line_number() {
        let err = TomlDoc::parse("threads = 2\npolicy = \"paper\"\nthreads = 4\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 3"), "{msg}");
        assert!(msg.contains("duplicate key"), "{msg}");
        assert!(msg.contains("line 1"), "must name the first definition: {msg}");

        // same key name under different tables is fine
        let doc = TomlDoc::parse("[serve]\nworkers = 2\n[tune]\nworkers = 4\n").unwrap();
        assert_eq!(doc.get_usize("serve.workers"), Some(2));
        assert_eq!(doc.get_usize("tune.workers"), Some(4));
    }

    #[test]
    fn duplicate_tables_error_with_line_number() {
        let err =
            TomlDoc::parse("[tune]\nbudget = 8\n[serve]\nworkers = 2\n[tune]\nbudget = 9\n")
                .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 5"), "{msg}");
        assert!(msg.contains("duplicate table [tune]"), "{msg}");
    }

    #[test]
    fn type_mismatches_return_none() {
        let doc = TomlDoc::parse("threads = \"two\"\n").unwrap();
        assert_eq!(doc.get_usize("threads"), None);
        assert_eq!(doc.get_str("threads"), Some("two"));
    }
}
