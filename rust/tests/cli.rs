//! Integration: the `courier` CLI binary (work-steps as subcommands).

use std::path::PathBuf;
use std::process::Command;

use courier::util::testing::{empty_hwdb_dir, TempDir};

fn courier_bin() -> PathBuf {
    // target/<profile>/courier next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("courier");
    assert!(p.exists(), "courier binary not built at {p:?}");
    p
}

fn run(args: &[&str]) -> (String, String, bool) {
    let (stdout, stderr, code) = run_code(args);
    (stdout, stderr, code == Some(0))
}

fn run_code(args: &[&str]) -> (String, String, Option<i32>) {
    let out = Command::new(courier_bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn courier");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.code(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["trace", "graph", "plan", "build", "run", "deploy", "serve", "tune", "synth"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_command_fails() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn unknown_flag_prints_usage_and_exits_2() {
    let (_, stderr, code) = run_code(&["trace", "--bogus", "x"]);
    assert_eq!(code, Some(2), "unknown flag must exit 2");
    assert!(stderr.contains("unknown flag --bogus"), "{stderr}");
    assert!(stderr.contains("USAGE"), "usage must be printed: {stderr}");
}

#[test]
fn equals_form_flags_are_accepted() {
    let dir = TempDir::new("cli-eq").unwrap();
    let trace = dir.path().join("t.json");
    let (stdout, stderr, ok) = run(&[
        "trace",
        "--program=corner_harris:48x64",
        "--frames=2",
        &format!("--out={}", trace.to_str().unwrap()),
    ]);
    assert!(ok, "trace with =-form flags failed: {stderr}");
    assert!(stdout.contains("traced 8 events over 2 frames"), "{stdout}");
    assert!(trace.exists());
}

#[test]
fn help_flag_prints_usage() {
    let (stdout, _, code) = run_code(&["--help"]);
    assert_eq!(code, Some(0));
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("serve"));
}

#[test]
fn trace_graph_plan_build_roundtrip() {
    let dir = TempDir::new("cli").unwrap();
    let trace = dir.path().join("t.json");
    let dot = dir.path().join("g.dot");
    let ir = dir.path().join("ir.json");
    let ctrl = dir.path().join("control.prog");

    let (stdout, stderr, ok) = run(&[
        "trace",
        "--program",
        "corner_harris:48x64",
        "--frames",
        "2",
        "--out",
        trace.to_str().unwrap(),
    ]);
    assert!(ok, "trace failed: {stderr}");
    assert!(stdout.contains("traced 8 events over 2 frames"), "{stdout}");

    let (stdout, stderr, ok) = run(&[
        "graph",
        "--trace",
        trace.to_str().unwrap(),
        "--dot",
        dot.to_str().unwrap(),
        "--ir",
        ir.to_str().unwrap(),
    ]);
    assert!(ok, "graph failed: {stderr}");
    assert!(stdout.contains("4 functions"), "{stdout}");
    let dot_text = std::fs::read_to_string(&dot).unwrap();
    assert!(dot_text.starts_with("digraph"));
    assert!(dot_text.contains("cv::cornerHarris"));

    let (stdout, stderr, ok) = run(&["plan", "--ir", ir.to_str().unwrap()]);
    assert!(ok, "plan failed: {stderr}");
    assert!(stdout.contains("Pipeline plan"), "{stdout}");
    assert!(stdout.contains("FPGA"), "{stdout}");

    let (_, stderr, ok) = run(&[
        "build",
        "--ir",
        ir.to_str().unwrap(),
        "--emit",
        ctrl.to_str().unwrap(),
    ]);
    assert!(ok, "build failed: {stderr}");
    let ctrl_text = std::fs::read_to_string(&ctrl).unwrap();
    assert!(ctrl_text.contains("serial_in_order"));
    assert!(ctrl_text.contains("token_pool"));
}

#[test]
fn deploy_reports_table1_and_speedup() {
    let (stdout, stderr, ok) = run(&[
        "deploy",
        "--program",
        "corner_harris:48x64",
        "--frames",
        "4",
    ]);
    assert!(ok, "deploy failed: {stderr}");
    assert!(stdout.contains("TABLE I"), "{stdout}");
    assert!(stdout.contains("Speed-up"), "{stdout}");
    assert!(stdout.contains("deployed:"), "{stdout}");
}

#[test]
fn serve_reports_warm_second_session() {
    // two sessions over one spec: the second must be a plan-cache hit.
    // An empty-but-valid module database keeps this hermetic (pure CPU
    // placement, no `make artifacts` needed).
    let dir = empty_hwdb_dir("cli-serve").unwrap();
    let (stdout, stderr, ok) = run(&[
        "--artifacts",
        dir.path().to_str().unwrap(),
        "serve",
        "--programs",
        "corner_harris:48x64",
        "--sessions",
        "2",
        "--frames",
        "4",
    ]);
    assert!(ok, "serve failed: {stderr}");
    assert!(stdout.contains("cold (built)"), "{stdout}");
    assert!(stdout.contains("warm (plan cache hit)"), "{stdout}");
    assert!(stdout.contains("SERVE: per-session report"), "{stdout}");
    assert!(stdout.contains("50% hit rate"), "{stdout}");
}

#[test]
fn tune_emits_report_with_rejections_and_persists_cost_db() {
    // the corner-Harris example spec through the autotuner: the TUNE
    // report must show at least one rejected candidate and a winner, and
    // the calibrated cost database must land on disk.  Hermetic: empty
    // module database -> CPU-only placement.
    let dir = empty_hwdb_dir("cli-tune").unwrap();
    let cost_db = dir.path().join("costs.json");
    let (stdout, stderr, ok) = run(&[
        "--artifacts",
        dir.path().to_str().unwrap(),
        "tune",
        "--program",
        "corner_harris:48x64",
        "--budget",
        "16",
        "--frames",
        "2",
        "--cost-db",
        cost_db.to_str().unwrap(),
    ]);
    assert!(ok, "tune failed: {stderr}");
    assert!(stdout.contains("TUNE: cornerHarris_Demo"), "{stdout}");
    assert!(stdout.contains("rejected"), "report must show a rejected candidate: {stdout}");
    assert!(stdout.contains("winner"), "{stdout}");
    assert!(stdout.contains("calibration:"), "{stdout}");
    assert!(stdout.contains("recommended: tokens ="), "{stdout}");
    assert!(cost_db.exists(), "cost db must be persisted");
    let text = std::fs::read_to_string(&cost_db).unwrap();
    assert!(text.contains("cv::cornerHarris@48x64#sw"), "{text}");
}

#[test]
fn synth_prints_tables_2_and_3() {
    let (stdout, stderr, ok) = run(&["synth", "--size", "48x64"]);
    assert!(ok, "synth failed: {stderr}");
    assert!(stdout.contains("TABLE II"), "{stdout}");
    assert!(stdout.contains("TABLE III"), "{stdout}");
    assert!(stdout.contains("hls_corner_harris"), "{stdout}");
    assert!(stdout.contains("Freq. [MHz]"), "{stdout}");
}

#[test]
fn edit_subcommand_round_trips() {
    let dir = TempDir::new("cli3").unwrap();
    let trace = dir.path().join("t.json");
    let ir = dir.path().join("ir.json");
    run(&["trace", "--program", "corner_harris:48x64", "--out", trace.to_str().unwrap()]);
    run(&["graph", "--trace", trace.to_str().unwrap(), "--ir", ir.to_str().unwrap()]);

    // pin normalize (step 2) to cpu, fuse 0:1
    let (stdout, stderr, ok) = run(&[
        "edit",
        "--ir",
        ir.to_str().unwrap(),
        "--fuse",
        "0:1",
        "--pin",
        "2=cpu",
    ]);
    assert!(ok, "edit failed: {stderr}");
    assert!(stdout.contains("fused steps 0..=1"), "{stdout}");
    assert!(stdout.contains("pinned step 2 -> cpu"), "{stdout}");
    assert!(stdout.contains("(3 functions)"), "{stdout}");

    let text = std::fs::read_to_string(&ir).unwrap();
    assert!(text.contains("cv::cvtColor+cv::cornerHarris"), "{text}");

    // bad edits fail loudly
    let (_, stderr, ok) = run(&["edit", "--ir", ir.to_str().unwrap(), "--fuse", "9:12"]);
    assert!(!ok);
    assert!(stderr.contains("fuse"), "{stderr}");
}

#[test]
fn policy_flag_changes_plan() {
    let dir = TempDir::new("cli2").unwrap();
    let trace = dir.path().join("t.json");
    let ir = dir.path().join("ir.json");
    run(&["trace", "--program", "corner_harris:48x64", "--out", trace.to_str().unwrap()]);
    run(&["graph", "--trace", trace.to_str().unwrap(), "--ir", ir.to_str().unwrap()]);
    let (single, _, ok1) =
        run(&["--policy", "single", "plan", "--ir", ir.to_str().unwrap()]);
    let (perf, _, ok2) =
        run(&["--policy", "per_function", "plan", "--ir", ir.to_str().unwrap()]);
    assert!(ok1 && ok2);
    assert!(single.contains("(1 stages"), "{single}");
    assert!(perf.contains("(4 stages"), "{perf}");
}
