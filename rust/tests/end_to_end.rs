//! Integration: the full Courier flow over real AOT artifacts + PJRT.
//!
//! Every test here requires `make artifacts` to have run; they fail loudly
//! (rather than skip) because the integration suite *is* the proof that
//! the three layers compose.

use std::path::PathBuf;
use std::sync::Arc;

use courier::app::{corner_harris_demo, edge_demo, Interpreter, RegistryDispatch};
use courier::config::{Config, PartitionPolicy};
use courier::hwdb::HwDatabase;
use courier::image::{synth, Mat};
use courier::ir::Ir;
use courier::offload::{Deployment, OffloadPath};
use courier::pipeline::TaskKind;
use courier::runtime::Runtime;
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph};

fn artifacts_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "integration tests need `make artifacts` (no manifest in {dir:?})"
    );
    dir
}

fn build_for(
    program: &courier::app::Program,
    cfg: &Config,
) -> (Ir, Arc<courier::pipeline::BuiltPipeline>) {
    let inputs: Vec<Vec<Mat>> = (0..2)
        .map(|s| {
            program
                .inputs
                .iter()
                .map(|(_, shape)| match shape.len() {
                    3 => synth::noise_rgb(shape[0], shape[1], s),
                    _ => synth::noise_gray(shape[0], shape[1], s),
                })
                .collect()
        })
        .collect();
    let trace = trace_program(program, &inputs).unwrap();
    let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
    let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let built = courier::pipeline::build(&ir, &db, &rt, &Registry::standard(), cfg).unwrap();
    (ir, Arc::new(built))
}

#[test]
fn corner_harris_all_steps_compose() {
    let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
    let program = corner_harris_demo(48, 64);
    let (ir, built) = build_for(&program, &cfg);

    // paper placement: 3 FPGA + 1 CPU
    assert_eq!(built.plan.placement_counts(), (3, 1));
    // normalize is the CPU task
    let sw_syms: Vec<&str> = built
        .plan
        .stages
        .iter()
        .flat_map(|s| &s.tasks)
        .filter(|t| matches!(t.kind, TaskKind::Sw))
        .map(|t| t.symbol.as_str())
        .collect();
    assert_eq!(sw_syms, vec!["cv::normalize"]);

    // deploy, stream, verify each frame against the unhooked binary
    let dep = Deployment::new(program.clone(), Arc::new(RegistryDispatch::standard()), built);
    let frames: Vec<Mat> = (0..6).map(|s| synth::noise_rgb(48, 64, 100 + s)).collect();
    let (outs, stats) = dep.run_stream(frames.clone()).unwrap();
    let stats = stats.expect("whole-program deployment must stream");
    assert_eq!(stats.frames, 6);
    let original = Interpreter::new(program, Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames.into_iter().enumerate() {
        let want = original.run(&[f]).unwrap().remove(0);
        assert!(
            outs[i].quantized_close(&want, 1.0, 1e-3),
            "frame {i}: max diff {}",
            outs[i].max_abs_diff(&want)
        );
    }
    assert_eq!(ir.funcs.len(), 4);
}

#[test]
fn edge_demo_db_miss_falls_back_to_cpu() {
    let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
    let program = edge_demo(48, 64);
    let (_, built) = build_for(&program, &cfg);
    // dilate has no enabled module -> CPU
    let dilate = built
        .plan
        .stages
        .iter()
        .flat_map(|s| &s.tasks)
        .find(|t| t.symbol == "cv::dilate")
        .expect("dilate task present");
    assert!(matches!(dilate.kind, TaskKind::Sw));
    // the five with modules are FPGA
    assert_eq!(built.plan.placement_counts().0, 5);

    // functional equivalence end-to-end
    let dep = Deployment::new(program.clone(), Arc::new(RegistryDispatch::standard()), built);
    let frame = synth::checkerboard(48, 64, 8);
    let got = dep.run_frame(&[frame.clone()]).unwrap().remove(0);
    let original = Interpreter::new(program, Arc::new(RegistryDispatch::standard()));
    let want = original.run(&[frame]).unwrap().remove(0);
    assert!(got.quantized_close(&want, 1.0, 2e-3)); // threshold flips possible
}

#[test]
fn every_enabled_module_matches_its_cpu_twin() {
    // The fundamental correctness contract of the mixed pipeline: for
    // every enabled image module and every compiled size, the artifact and
    // the swlib implementation agree.
    let dir = artifacts_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();
    let mut checked = 0;
    for m in &db.manifest().modules {
        if !m.enabled || m.kind == "gemm" {
            continue;
        }
        if !registry.contains(&m.library_symbol) {
            continue; // fused module: composition is tested elsewhere
        }
        // smallest variant keeps the test fast
        let v = m
            .variants
            .iter()
            .min_by_key(|v| v.size.iter().product::<usize>())
            .unwrap();
        let exe = rt.load_hlo_text(&dir.join(&v.artifact)).unwrap();
        let input = match v.inputs[0].shape.len() {
            3 => synth::noise_rgb(v.inputs[0].shape[0], v.inputs[0].shape[1], 7),
            _ => synth::noise_gray(v.inputs[0].shape[0], v.inputs[0].shape[1], 7),
        };
        let hw = exe.run(&[&input]).unwrap();
        let sw = registry.call(&m.library_symbol, &[&input]).unwrap();
        let scale = sw.max().abs().max(sw.min().abs()).max(1.0);
        assert!(
            hw.allclose(&sw, 1e-3, 1e-3 * scale),
            "{}: hw vs sw max diff {} (scale {scale})",
            m.name,
            hw.max_abs_diff(&sw)
        );
        checked += 1;
    }
    assert!(checked >= 6, "only {checked} modules checked");
}

#[test]
fn gemm_module_matches_blas() {
    let dir = artifacts_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let a = synth::random_matrix(128, 128, 1);
    let b = synth::random_matrix(128, 128, 2);
    let hit = db
        .lookup("blas::sgemm", &[&[128, 128][..], &[128, 128][..]])
        .expect("gemm module");
    let exe = rt.load_hlo_text(&hit.artifact_path(&db)).unwrap();
    let hw = exe.run(&[&a, &b]).unwrap();
    let sw = courier::swlib::blas::sgemm(&a, &b).unwrap();
    assert!(hw.allclose(&sw, 1e-3, 1e-2), "max diff {}", hw.max_abs_diff(&sw));
}

#[test]
fn missing_artifact_file_fails_cleanly() {
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let err = rt.load_hlo_text(&dir.join("hls_nonexistent__1x1.hlo.txt")).unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn shape_mismatch_fails_cleanly() {
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&dir.join("hls_threshold__48x64.hlo.txt")).unwrap();
    // wrong shape: the PJRT layer rejects it (donated error, not UB)
    let wrong = synth::noise_gray(32, 32, 0);
    assert!(exe.run(&[&wrong]).is_err());
}

#[test]
fn corrupted_artifact_fails_cleanly() {
    use courier::util::testing::TempDir;
    let tmp = TempDir::new("corrupt").unwrap();
    let bad = tmp.path().join("bad.hlo.txt");
    std::fs::write(&bad, "HloModule broken\n\nENTRY main {\n  this is not hlo\n}\n").unwrap();
    let rt = Runtime::cpu().unwrap();
    // compile happens on the fabric thread; the error must surface as a
    // clean Err, not a crash or hang
    assert!(rt.load_hlo_text(&bad).is_err());

    let truncated = tmp.path().join("trunc.hlo.txt");
    let real = std::fs::read_to_string(artifacts_dir().join("hls_threshold__48x64.hlo.txt")).unwrap();
    std::fs::write(&truncated, &real[..real.len() / 2]).unwrap();
    assert!(rt.load_hlo_text(&truncated).is_err());
}

#[test]
fn corrupted_manifest_fails_cleanly() {
    use courier::util::testing::TempDir;
    let tmp = TempDir::new("badmanifest").unwrap();
    std::fs::write(tmp.path().join("manifest.json"), "{\"version\": 99}").unwrap();
    let err = HwDatabase::load(tmp.path()).unwrap_err();
    assert!(err.to_string().contains("json") || err.to_string().contains("version"), "{err}");

    std::fs::write(tmp.path().join("manifest.json"), "not json at all").unwrap();
    assert!(HwDatabase::load(tmp.path()).is_err());
}

#[test]
fn new_library_modules_served_end_to_end() {
    // the paper claims adding library functions is easy: laplacian, scharr
    // and medianBlur were added as one catalog row each — trace a program
    // using them, build, deploy, verify.
    let prog = courier::app::parse_program(
        "program extra_demo\n\
         input frame 48x64x3\n\
         call gray = cv::cvtColor(frame)\n\
         call med = cv::medianBlur(gray)\n\
         call lap = cv::Laplacian(med)\n\
         call mag = cv::convertScaleAbs(lap)\n\
         output mag\n",
    )
    .unwrap();
    let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
    let (_, built) = build_for(&prog, &cfg);
    assert_eq!(built.plan.placement_counts().0, 4, "all four on the fabric");
    let dep = Deployment::new(prog.clone(), Arc::new(RegistryDispatch::standard()), built);
    let frame = synth::checkerboard(48, 64, 8);
    let got = dep.run_frame(&[frame.clone()]).unwrap().remove(0);
    let original = Interpreter::new(prog, Arc::new(RegistryDispatch::standard()));
    let want = original.run(&[frame]).unwrap().remove(0);
    assert!(got.quantized_close(&want, 1.0, 1e-3), "max diff {}", got.max_abs_diff(&want));
}

#[test]
fn switcher_round_trip_under_load() {
    let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
    let program = corner_harris_demo(48, 64);
    let (_, built) = build_for(&program, &cfg);
    let dep = Deployment::new(program, Arc::new(RegistryDispatch::standard()), built);
    let frame = synth::noise_rgb(48, 64, 3);

    let offloaded = dep.run_frame(std::slice::from_ref(&frame)).unwrap().remove(0);
    dep.switcher().set(OffloadPath::Original);
    let original = dep.run_frame(std::slice::from_ref(&frame)).unwrap().remove(0);
    dep.switcher().set(OffloadPath::Offloaded);
    let offloaded2 = dep.run_frame(std::slice::from_ref(&frame)).unwrap().remove(0);

    assert!(offloaded.quantized_close(&original, 1.0, 1e-3));
    assert_eq!(offloaded, offloaded2, "offloaded path must be deterministic");
}

#[test]
fn policies_agree_on_results_differ_on_structure() {
    let program = corner_harris_demo(48, 64);
    let frame = synth::noise_rgb(48, 64, 11);
    let mut outs: Vec<Mat> = Vec::new();
    let mut stage_counts = Vec::new();
    for policy in [
        PartitionPolicy::Paper,
        PartitionPolicy::Optimal,
        PartitionPolicy::PerFunction,
        PartitionPolicy::Single,
    ] {
        let cfg = Config { artifacts_dir: artifacts_dir(), policy, ..Default::default() };
        let (_, built) = build_for(&program, &cfg);
        stage_counts.push(built.plan.stages.len());
        outs.push(built.process_one(frame.clone()).unwrap());
    }
    for pair in outs.windows(2) {
        assert!(pair[0].quantized_close(&pair[1], 1.0, 1e-3), "policies disagree on data");
    }
    assert_eq!(stage_counts[2], 4); // per-function
    assert_eq!(stage_counts[3], 1); // single
    assert!(stage_counts[0] <= 3); // paper: threads+1
}

#[test]
fn multi_size_variants_all_build() {
    // the corner-harris demo must build at every compiled image size
    for (h, w) in [(48, 64), (240, 320)] {
        let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
        let program = corner_harris_demo(h, w);
        let (_, built) = build_for(&program, &cfg);
        let out = built.process_one(synth::noise_rgb(h, w, 0)).unwrap();
        assert_eq!(out.shape(), &[h, w]);
    }
}

#[test]
fn unknown_size_fails_with_db_context() {
    // 47x63 was never AOT-compiled: lookup misses, so everything lands on
    // the CPU — the binary still runs (graceful degradation), just without
    // acceleration.
    let cfg = Config { artifacts_dir: artifacts_dir(), ..Default::default() };
    let program = corner_harris_demo(47, 63);
    let (_, built) = build_for(&program, &cfg);
    assert_eq!(built.plan.placement_counts().0, 0, "no hw for unknown size");
    let out = built.process_one(synth::noise_rgb(47, 63, 0)).unwrap();
    assert_eq!(out.shape(), &[47, 63]);
}
