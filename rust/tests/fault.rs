//! Integration: fault injection, containment, and hw→sw failover.
//!
//! The software-fault tests run hermetically (an empty hardware manifest
//! places everything on the CPU, so the injected `sw_panic` schedule is
//! the only failure source).  The hardware-fault tests — transient DMA
//! timeouts driving quarantine/probation, and a wedged fabric module
//! bounded by the frame deadline — need real artifacts and skip without
//! `make artifacts`, like the runtime unit tests.
//!
//! `COURIER_FAULT_SEED` overrides the injection seed (the CI fault
//! matrix sweeps it); every assertion here is seed-independent — period
//! schedules don't consult the seed, and the probabilistic storm test
//! asserts properties (delivery, ordering, accounting), not positions.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use courier::app::{corner_harris_demo, harris_dag_demo, Interpreter, Program, RegistryDispatch};
use courier::config::Config;
use courier::image::{synth, Mat};
use courier::serve::{Server, SessionSpec};
use courier::util::testing::empty_hwdb_dir;

fn seed_from_env() -> u64 {
    std::env::var("COURIER_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// Serve config with `sw_panic` injection armed (rate left to the test).
fn fault_config(artifacts_dir: PathBuf) -> Config {
    let mut cfg = Config { artifacts_dir, ..Default::default() };
    cfg.serve.workers = 1;
    cfg.serve.queue_depth = 32;
    cfg.fault.enabled = true;
    cfg.fault.seed = seed_from_env();
    cfg.fault.kinds = "sw_panic".to_string();
    cfg
}

/// The hardware modules the planner places for `program` — computed via
/// the same trace → IR → plan chain serve's cold build runs, so a test
/// can aim its `[fault] only` filter at a module that is really placed.
fn placed_hw_modules(dir: &Path, program: &Program) -> Vec<String> {
    let db = courier::hwdb::HwDatabase::load(dir).unwrap();
    let inputs = courier::app::synth_frames(program, 1);
    let trace = courier::trace::trace_program(program, &inputs).unwrap();
    let ir = courier::ir::Ir::from_graph(&courier::trace::CallGraph::from_trace(&trace)).unwrap();
    let registry = courier::swlib::Registry::standard();
    let cfg = Config { artifacts_dir: dir.to_path_buf(), ..Default::default() };
    let plan = courier::pipeline::plan_pipeline(&ir, &db, &registry, &cfg, None).unwrap();
    plan.hw_modules()
}

#[test]
fn period_schedule_faults_exact_frames_and_spares_the_rest() {
    // one worker serves frames in submit order, and `cv::harrisResponse`
    // runs exactly once per frame, so a period-4 schedule on that site
    // strikes exactly frames 3, 7, 11, … — a fully deterministic replay
    let tmp = empty_hwdb_dir("fault-period").unwrap();
    let mut cfg = fault_config(tmp.path().to_path_buf());
    cfg.fault.period = 4;
    cfg.fault.only = "harrisResponse".to_string();
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(harris_dag_demo(24, 32))).unwrap();

    let frames: Vec<Mat> = (0..24).map(|s| synth::noise_rgb(24, 32, s)).collect();
    let tickets: Vec<_> = frames.iter().map(|f| session.submit(f.clone()).unwrap()).collect();
    let results: Vec<_> = tickets.into_iter().map(|t| session.wait(t)).collect();

    let original =
        Interpreter::new(harris_dag_demo(24, 32), Arc::new(RegistryDispatch::standard()));
    for (i, (frame, result)) in frames.into_iter().zip(results).enumerate() {
        if (i + 1) % 4 == 0 {
            let err = result.expect_err("scheduled frame must fault");
            assert!(err.to_string().contains("injected"), "frame {i}: {err}");
        } else {
            let want = original.run(&[frame]).unwrap().remove(0);
            assert_eq!(result.unwrap(), want, "frame {i}: non-faulted output diverges");
        }
    }
    assert_eq!(session.stats.completed.get(), 18);
    assert_eq!(session.stats.failed.get(), 6);
    assert_eq!(session.stats.in_flight(), 0);
    assert_eq!(server.stats().frame_faults.get(), 6);
    assert_eq!(server.stats().retries.get(), 0, "no hardware, no sw twin, no retries");
    assert_eq!(server.stats().quarantines.get(), 0, "software faults never quarantine");
    server.shutdown();
}

#[test]
fn seeded_fault_storm_delivers_every_nonfaulted_frame_in_order() {
    // the acceptance drill: a 5 % per-invocation fault rate over 500
    // served frames with two workers racing.  No hangs (every wait
    // returns), no corruption (each delivered frame matches the
    // interpreter on its *own* input — a cross-frame mixup would fail
    // loudly), and the books balance exactly
    let tmp = empty_hwdb_dir("fault-storm").unwrap();
    let mut cfg = fault_config(tmp.path().to_path_buf());
    cfg.serve.workers = 2;
    cfg.fault.probability = 0.05;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(harris_dag_demo(24, 32))).unwrap();

    const FRAMES: u64 = 500;
    let frames: Vec<Mat> = (0..FRAMES).map(|s| synth::noise_rgb(24, 32, s)).collect();
    let tickets: Vec<_> = frames.iter().map(|f| session.submit(f.clone()).unwrap()).collect();
    let results: Vec<_> = tickets.into_iter().map(|t| session.wait(t)).collect();

    let original =
        Interpreter::new(harris_dag_demo(24, 32), Arc::new(RegistryDispatch::standard()));
    let mut failed = 0u64;
    for (i, (frame, result)) in frames.into_iter().zip(results).enumerate() {
        match result {
            Ok(out) => {
                let want = original.run(&[frame]).unwrap().remove(0);
                assert_eq!(out, want, "frame {i}: delivered output is not its own input's");
            }
            Err(err) => {
                assert!(err.to_string().contains("injected"), "frame {i}: {err}");
                failed += 1;
            }
        }
    }
    assert!(failed > 0, "a 5 % rate over {FRAMES} frames must strike at least once");
    assert!(failed < FRAMES, "a 5 % rate must not strike every frame");
    assert_eq!(session.stats.failed.get(), failed);
    assert_eq!(session.stats.completed.get(), FRAMES - failed);
    assert_eq!(session.stats.in_flight(), 0);
    assert_eq!(server.stats().frame_faults.get(), failed);
    server.shutdown();
}

#[test]
fn transient_hw_faults_retry_on_the_twin_then_quarantine_and_readmit() {
    // needs real artifacts: DMA timeouts are injected on one placed
    // module's fabric thread (skips without `make artifacts`)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let program = corner_harris_demo(48, 64);
    let placed = placed_hw_modules(&dir, &program);
    if placed.is_empty() {
        return; // nothing on the fabric: the failover path cannot engage
    }

    // period-2 timeouts on the first placed module, capped at 4 total:
    // with one worker the hw site sees one invocation per hardware
    // frame, so the walk is exact —
    //   f0 ok, f1 fault #1 (retry), f2 ok, f3 fault #2 → QUARANTINE;
    //   f4/f6/… steered to the twin, every 2nd steered frame probes:
    //   f5 probe ok, f7 probe fault #3, f9 probe ok, f11 probe fault #4
    //   (cap reached — the schedule runs clean from here),
    //   f13 probe ok, f15 probe ok → RE-ADMITTED; f16–f19 back on hw
    let mut cfg = Config { artifacts_dir: dir, ..Default::default() };
    cfg.serve.workers = 1;
    cfg.serve.queue_depth = 32;
    cfg.serve.quarantine_threshold = 2;
    cfg.serve.quarantine_window = 10;
    cfg.serve.probation_frames = 2;
    cfg.serve.probe_every = 2;
    cfg.fault.enabled = true;
    cfg.fault.seed = seed_from_env();
    cfg.fault.kinds = "dma_timeout".to_string();
    cfg.fault.period = 2;
    cfg.fault.only = placed[0].clone();
    cfg.fault.max_faults = 4;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(corner_harris_demo(48, 64))).unwrap();
    assert!(!session.pipeline().plan.hw_modules().is_empty());

    let frames: Vec<Mat> = (0..20).map(|s| synth::noise_rgb(48, 64, s)).collect();
    let outs = session.run_window(frames.clone()).unwrap();

    // every frame was delivered — the faulted ones via the sw twin, the
    // steered ones on the twin outright, the rest on hardware — and all
    // of them agree with the original binary
    let original =
        Interpreter::new(corner_harris_demo(48, 64), Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames.into_iter().enumerate() {
        let want = original.run(&[f]).unwrap().remove(0);
        assert!(outs[i].quantized_close(&want, 1.0, 1e-3), "frame {i} diverges");
    }
    assert_eq!(session.stats.completed.get(), 20);
    assert_eq!(session.stats.failed.get(), 0, "every faulted frame must be saved by a retry");

    let stats = server.stats();
    assert_eq!(stats.frame_faults.get(), 4, "the injected schedule strikes exactly 4 frames");
    assert_eq!(stats.retries.get(), 4, "each faulted frame retries once on the twin");
    assert!(stats.quarantines.get() >= 1, "the fault burst must quarantine");
    assert_eq!(
        stats.probation_readmissions.get(),
        stats.quarantines.get(),
        "every quarantined module must be re-admitted after the schedule drains"
    );
    assert!(
        server.health().quarantined().is_empty(),
        "probation re-admitted everything: {:?}",
        server.health().quarantined()
    );
    server.shutdown();
}

#[test]
fn frame_deadline_bounds_a_wedged_fabric_module() {
    // needs real artifacts: a fabric_hang wedges one module's fabric
    // thread past the frame deadline (skips without `make artifacts`)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let program = corner_harris_demo(48, 64);
    let placed = placed_hw_modules(&dir, &program);
    if placed.is_empty() {
        return;
    }

    // every 3rd invocation wedges for 150 ms; the 100 ms deadline cuts
    // the wait, the twin redelivers, and the worker survives to serve
    // the next frame.  hang < 2 × deadline keeps the wedge from bleeding
    // into the following frame's invocation.  The threshold is parked
    // high so the transient wedges never quarantine
    let mut cfg = Config { artifacts_dir: dir, ..Default::default() };
    cfg.serve.workers = 1;
    cfg.serve.queue_depth = 32;
    cfg.serve.frame_deadline_ms = 100;
    cfg.serve.quarantine_threshold = 10;
    cfg.fault.enabled = true;
    cfg.fault.seed = seed_from_env();
    cfg.fault.kinds = "fabric_hang".to_string();
    cfg.fault.period = 3;
    cfg.fault.only = placed[0].clone();
    cfg.fault.hang_ms = 150;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(corner_harris_demo(48, 64))).unwrap();

    let frames: Vec<Mat> = (0..6).map(|s| synth::noise_rgb(48, 64, 100 + s)).collect();
    let outs = session.run_window(frames.clone()).unwrap();

    let original =
        Interpreter::new(corner_harris_demo(48, 64), Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames.into_iter().enumerate() {
        let want = original.run(&[f]).unwrap().remove(0);
        assert!(outs[i].quantized_close(&want, 1.0, 1e-3), "frame {i} diverges");
    }
    assert_eq!(session.stats.completed.get(), 6);
    assert_eq!(session.stats.failed.get(), 0);

    let stats = server.stats();
    assert_eq!(stats.frame_faults.get(), 2, "invocations 2 and 5 wedge past the deadline");
    assert_eq!(stats.retries.get(), 2);
    assert_eq!(stats.quarantines.get(), 0, "two wedges stay under the parked threshold");
    server.shutdown();
}
