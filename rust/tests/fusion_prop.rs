//! Seeded property suite for the generalized SW-chain fusion planner and
//! move-aware fork-join scheduling.
//!
//! 1. **Fused == unfused, bit for bit.**  Random unary software chains
//!    (length 2–6, random shapes including degenerate 1×N / N×1 images)
//!    are built twice — default partition, and regrouped into one
//!    sequential stage so the planner fuses the whole run — and both must
//!    match the plain interpreter exactly on every frame.
//! 2. **Move-aware fork-join.**  On the generic (non-pair) fork-join
//!    path, the last sibling consumer of a dying buffer receives it
//!    moved; only the earlier siblings clone.  Pinned via the pool's
//!    clone counter: exactly one pool clone per fork per frame where the
//!    pre-move-aware scheduler paid one per sibling.

use courier::app::{parse_program, Interpreter, Program, RegistryDispatch};
use courier::config::Config;
use courier::hwdb::HwDatabase;
use courier::image::{synth, Mat};
use courier::ir::Ir;
use courier::pipeline::{build, instantiate, BuiltPipeline, StagePlan, StageSpec, TaskSpec};
use courier::runtime::Runtime;
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph};
use courier::util::rng::Rng;
use courier::util::testing::empty_hwdb_dir;

/// Unary, shape-preserving standard kernels the chain generator samples.
const UNARY: &[&str] = &[
    "cv::Sobel",
    "cv::SobelY",
    "cv::GaussianBlur",
    "cv::boxFilter",
    "cv::erode",
    "cv::dilate",
    "cv::Laplacian",
    "cv::Scharr",
    "cv::medianBlur",
    "cv::cornerHarris",
    "cv::normalize",
    "cv::convertScaleAbs",
    "cv::threshold",
];

fn chain_program(symbols: &[&str], h: usize, w: usize) -> Program {
    let mut text = format!("program chainProp\ninput x0 {h}x{w}\n");
    for (i, sym) in symbols.iter().enumerate() {
        text.push_str(&format!("call x{} = {}(x{})\n", i + 1, sym, i));
    }
    text.push_str(&format!("output x{}\n", symbols.len()));
    parse_program(&text).unwrap()
}

fn flat_tasks(built: &BuiltPipeline) -> Vec<TaskSpec> {
    built
        .plan
        .stages
        .iter()
        .flat_map(|s| s.tasks.iter().cloned())
        .collect()
}

#[test]
fn random_unary_chains_fuse_bit_for_bit() {
    let mut rng = Rng::new(0x5EEDED);
    // random shapes plus the degenerate row/column/pixel images
    let shapes: [(usize, usize); 5] = [(9, 11), (1, 13), (13, 1), (1, 1), (16, 8)];
    let tmp = empty_hwdb_dir("fusion-prop").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();
    let interp_dispatch = std::sync::Arc::new(RegistryDispatch::standard());

    for len in 2..=6usize {
        let (h, w) = shapes[len - 2];
        let symbols: Vec<&str> = (0..len).map(|_| UNARY[rng.below(UNARY.len())]).collect();
        let prog = chain_program(&symbols, h, w);
        let trace = trace_program(&prog, &[vec![synth::noise_gray(h, w, len as u64)]]).unwrap();
        let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
        assert!(ir.is_chain(), "{symbols:?}: unary chain must lower as a chain");

        let cfg = Config {
            artifacts_dir: tmp.path().to_path_buf(),
            cpu_only: true,
            threads: 1,
            tokens: 2,
            ..Default::default()
        };
        let built = build(&ir, &db, &rt, &registry, &cfg).unwrap();

        // regroup into ONE sequential stage: the planner must fuse the
        // entire run into a single composed binding
        let fused = instantiate(
            &StagePlan {
                program: built.plan.program.clone(),
                threads: 1,
                tokens: 2,
                bands: 1,
                edges: built.plan.edges.clone(),
                outputs: built.plan.outputs.clone(),
                stages: vec![StageSpec { index: 0, serial: true, tasks: flat_tasks(&built) }],
            },
            db.dir(),
            &rt,
            &registry,
        )
        .unwrap();
        let labels = fused.pipeline.stage_labels();
        assert_eq!(labels.len(), 1);
        assert_eq!(
            labels[0].matches('+').count(),
            len - 1,
            "{symbols:?}: whole run must fuse, got label {:?}",
            labels[0]
        );

        let interp = Interpreter::new(prog, interp_dispatch.clone());
        for fseed in 0..2u64 {
            let frame = synth::noise_gray(h, w, 100 + fseed);
            let want = interp.run(&[frame.clone()]).unwrap().remove(0);
            assert_eq!(
                fused.process_one(frame.clone()).unwrap(),
                want,
                "{symbols:?} @{h}x{w} seed {fseed}: fused diverges"
            );
            assert_eq!(
                built.process_one(frame).unwrap(),
                want,
                "{symbols:?} @{h}x{w} seed {fseed}: unfused diverges"
            );
        }
        // streamed through the fused pipeline (pool-backed steady state)
        let frames: Vec<Mat> = (0..4).map(|s| synth::noise_gray(h, w, 200 + s)).collect();
        let (outs, _) = fused.run(frames.clone()).unwrap();
        for (i, f) in frames.into_iter().enumerate() {
            assert_eq!(
                outs[i],
                interp.run(&[f]).unwrap().remove(0),
                "{symbols:?}: streamed frame {i} diverges"
            );
        }
    }
}

#[test]
fn random_chains_inside_fork_join_branches_fuse_bit_for_bit() {
    // Property 3: the fusion planner walks *branches*, not just whole
    // sequential stages.  A fork-join stage whose second branch is a
    // random unary chain must fuse that chain into one composed binding
    // (label `a || s1+s2+...`), stay bit-identical to the interpreter,
    // and — per-link provenance gating — stop fusing at a re-registered
    // symbol while the intact prefix still fuses.
    let mut rng = Rng::new(0xF0524A01);
    let tmp = empty_hwdb_dir("fusion-prop-branch").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let interp_dispatch = std::sync::Arc::new(RegistryDispatch::standard());
    // symbols the fixed skeleton already uses must not appear in the
    // sampled chain, so the provenance override below hits exactly one
    // call site
    let reserved = ["cv::Sobel", "cv::cornerHarris"];

    for len in 2..=4usize {
        let (h, w) = (10 + len, 12);
        let mut symbols: Vec<&str> = Vec::new();
        while symbols.len() < len {
            let s = UNARY[rng.below(UNARY.len())];
            if !symbols.contains(&s) && !reserved.contains(&s) {
                symbols.push(s);
            }
        }
        let mut text = format!(
            "program fjBranchProp\n\
             input x {h}x{w}x3\n\
             call gray = cv::cvtColor(x)\n\
             call a = cv::Sobel(gray)\n\
             call b1 = {}(gray)\n",
            symbols[0]
        );
        for (i, sym) in symbols.iter().enumerate().skip(1) {
            text.push_str(&format!("call b{} = {}(b{})\n", i + 1, sym, i));
        }
        text.push_str(&format!(
            "call join = cv::harrisResponse(a, b{len})\n\
             call out = cv::normalize(join)\n\
             output out\n"
        ));
        let prog = parse_program(&text).unwrap();
        let trace = trace_program(&prog, &[vec![synth::noise_rgb(h, w, len as u64)]]).unwrap();
        let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
        let registry = Registry::standard();
        let cfg = Config {
            artifacts_dir: tmp.path().to_path_buf(),
            cpu_only: true,
            threads: 2,
            tokens: 2,
            ..Default::default()
        };
        let built = build(&ir, &db, &rt, &registry, &cfg).unwrap();
        let tasks = flat_tasks(&built);
        assert_eq!(tasks.len(), len + 4, "{symbols:?}");

        // regroup so the Sobel branch and the whole chain share one
        // fork-join stage
        let regrouped = StagePlan {
            program: built.plan.program.clone(),
            threads: 2,
            tokens: 2,
            bands: 1,
            edges: built.plan.edges.clone(),
            outputs: built.plan.outputs.clone(),
            stages: vec![
                StageSpec { index: 0, serial: true, tasks: tasks[0..1].to_vec() },
                StageSpec { index: 1, serial: false, tasks: tasks[1..len + 2].to_vec() },
                StageSpec { index: 2, serial: true, tasks: tasks[len + 2..len + 4].to_vec() },
            ],
        };
        let fj = instantiate(&regrouped, db.dir(), &rt, &registry).unwrap();
        let labels = fj.pipeline.stage_labels();
        assert_eq!(labels.len(), 3);
        assert_eq!(
            labels[1],
            format!("cv::Sobel || {}", symbols.join("+")),
            "{symbols:?}: in-branch chain must fuse"
        );

        let interp = Interpreter::new(prog, interp_dispatch.clone());
        for fseed in 0..2u64 {
            let frame = synth::noise_rgb(h, w, 300 + fseed);
            let want = interp.run(&[frame.clone()]).unwrap().remove(0);
            assert_eq!(
                fj.process_one(frame).unwrap(),
                want,
                "{symbols:?} seed {fseed}: branch-fused diverges"
            );
        }
        let frames: Vec<Mat> = (0..4).map(|s| synth::noise_rgb(h, w, 400 + s)).collect();
        let (outs, _) = fj.run(frames.clone()).unwrap();
        for (i, f) in frames.into_iter().enumerate() {
            assert_eq!(
                outs[i],
                interp.run(&[f]).unwrap().remove(0),
                "{symbols:?}: streamed frame {i} diverges"
            );
        }

        // re-register the chain's LAST symbol: the link into it is no
        // longer provenance-intact, so the prefix fuses and the patched
        // tail binds alone
        let mut patched = Registry::standard();
        let last = symbols[len - 1];
        patched.register(
            last,
            1,
            std::sync::Arc::new(|a: &[&Mat]| {
                let mut m = a[0].clone();
                for v in m.as_mut_slice() {
                    *v = *v * 0.5 + 3.0;
                }
                Ok(m)
            }),
        );
        let split = instantiate(&regrouped, db.dir(), &rt, &patched).unwrap();
        let want_label = format!("cv::Sobel || {} || {last}", symbols[..len - 1].join("+"));
        assert_eq!(
            split.pipeline.stage_labels()[1],
            want_label,
            "{symbols:?}: fusion must stop at the overridden link"
        );
        // and the override's semantics flow through the fork-join stage
        let frame = synth::noise_rgb(h, w, 777);
        let gray = patched.call("cv::cvtColor", &[&frame]).unwrap();
        let a = patched.call("cv::Sobel", &[&gray]).unwrap();
        let mut b = gray;
        for sym in &symbols {
            b = patched.call(sym, &[&b]).unwrap();
        }
        let join = patched.call("cv::harrisResponse", &[&a, &b]).unwrap();
        let want = patched.call("cv::normalize", &[&join]).unwrap();
        assert_eq!(split.process_one(frame).unwrap(), want, "{symbols:?}: override lost");
    }
}

#[test]
fn fork_join_last_sibling_moves_instead_of_cloning() {
    // harris_dag with cv::Sobel overridden: the override disables the
    // fused one-walk pair, so the stage takes the generic fork-join
    // path.  Both siblings consume the dying gray buffer; move-aware
    // scheduling clones for the first and MOVES it into the last —
    // exactly one pool clone per fork per frame (the pre-move-aware
    // scheduler cloned once per sibling: two).
    let tmp = empty_hwdb_dir("fusion-prop-fj").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut registry = Registry::standard();
    let cfg = Config {
        artifacts_dir: tmp.path().to_path_buf(),
        cpu_only: true,
        ..Default::default()
    };
    let prog = courier::app::harris_dag_demo(16, 16);
    let trace = trace_program(&prog, &[vec![synth::noise_rgb(16, 16, 0)]]).unwrap();
    let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
    let built = build(&ir, &db, &rt, &registry, &cfg).unwrap();
    let tasks = flat_tasks(&built);
    assert_eq!(tasks.len(), 6);
    let regrouped = StagePlan {
        program: built.plan.program.clone(),
        threads: 2,
        tokens: 4,
        bands: 1,
        edges: built.plan.edges.clone(),
        outputs: built.plan.outputs.clone(),
        stages: vec![
            StageSpec { index: 0, serial: true, tasks: tasks[0..1].to_vec() },
            StageSpec { index: 1, serial: false, tasks: tasks[1..3].to_vec() },
            StageSpec { index: 2, serial: true, tasks: tasks[3..6].to_vec() },
        ],
    };
    registry.register(
        "cv::Sobel",
        1,
        std::sync::Arc::new(|a: &[&Mat]| {
            let mut g = courier::swlib::imgproc::sobel(a[0], 1, 0)?;
            for v in g.as_mut_slice() {
                *v *= 2.0;
            }
            Ok(g)
        }),
    );
    assert!(!registry.sobel_pair_intact());
    let fj = instantiate(&regrouped, db.dir(), &rt, &registry).unwrap();
    assert!(
        fj.pipeline.stage_labels()[1].contains(" || "),
        "override must force the generic fork-join path: {:?}",
        fj.pipeline.stage_labels()
    );

    // correctness first: the override really runs
    let frame = synth::noise_rgb(16, 16, 7);
    let gray = registry.call("cv::cvtColor", &[&frame]).unwrap();
    let ix = registry.call("cv::Sobel", &[&gray]).unwrap();
    let iy = registry.call("cv::SobelY", &[&gray]).unwrap();
    let resp = registry.call("cv::harrisResponse", &[&ix, &iy]).unwrap();
    let norm = registry.call("cv::normalize", &[&resp]).unwrap();
    let want = registry.call("cv::convertScaleAbs", &[&norm]).unwrap();
    assert_eq!(fj.process_one(frame).unwrap(), want);

    // clone accounting: the only pool clone on the whole frame path is
    // the first sibling's copy of gray — the last sibling borrows the
    // moved original
    let warm_clones = fj.pool.stats().cloned;
    const FRAMES: u64 = 8;
    let frames: Vec<Mat> = (0..FRAMES).map(|s| synth::noise_rgb(16, 16, 50 + s)).collect();
    let (outs, _) = fj.run(frames).unwrap();
    assert_eq!(outs.len(), FRAMES as usize);
    let clones = fj.pool.stats().cloned - warm_clones;
    assert_eq!(
        clones, FRAMES,
        "move-aware fork-join must clone exactly once per fork per frame \
         (one shared dying buffer, two siblings): got {clones} over {FRAMES} frames"
    );
}

/// Random Courier-Script source: a `const` declaration, `let`/`call`
/// synonyms, fan-out from arbitrary earlier buffers, scalar-bearing and
/// shape-halving calls, and 1–3 `output` declarations (one per branch
/// tail — a later branch may fork *from* an earlier tail, so a declared
/// output can also be consumed downstream).  Each (parent, call) pair is
/// sampled at most once: the tracer links calls by content hash, and two
/// identical applications would alias.
fn random_script(rng: &mut Rng, h: usize, w: usize) -> String {
    let mut text = format!(
        "program scriptProp\n\
         input frame {h}x{w}x3\n\
         const k = 0.05\n\
         let gray = cv::cvtColor(frame)\n"
    );
    let mut names: Vec<String> = vec!["gray".into()];
    let mut seen: Vec<String> = Vec::new();
    let mut outputs: Vec<String> = Vec::new();
    let branches = 1 + rng.below(3);
    for b in 0..branches {
        let mut cur = names[rng.below(names.len())].clone();
        for i in 0..1 + rng.below(3) {
            let name = format!("b{b}_{i}");
            let call = loop {
                let call = match rng.below(UNARY.len() + 3) {
                    c if c < UNARY.len() => format!("{}({cur})", UNARY[c]),
                    c if c == UNARY.len() => format!("cv::pyrDown({cur})"),
                    c if c == UNARY.len() + 1 => format!("cv::threshold({cur}, 64, 255)"),
                    _ => format!("cv::cornerHarris({cur}, k)"),
                };
                if !seen.contains(&call) {
                    break call;
                }
            };
            seen.push(call.clone());
            let kw = if rng.below(2) == 0 { "let" } else { "call" };
            text.push_str(&format!("{kw} {name} = {call}\n"));
            names.push(name.clone());
            cur = name;
        }
        outputs.push(cur);
    }
    for out in &outputs {
        text.push_str(&format!("output {out}\n"));
    }
    text
}

#[test]
fn random_courier_scripts_round_trip_bit_for_bit() {
    // Property 4: the whole front end round-trips.  Random Courier-Script
    // sources (fan-out, consts, multi-output) parse, trace, lower with
    // declared outputs, build under random thread/token counts, and
    // stream ordered bundles bit-identical to the interpreter.
    let mut rng = Rng::new(0xC0DE5C21);
    let tmp = empty_hwdb_dir("script-prop").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();
    let dispatch = std::sync::Arc::new(RegistryDispatch::standard());

    for case in 0..8u64 {
        let (h, w) = (8 + rng.below(9), 8 + rng.below(9));
        let text = random_script(&mut rng, h, w);
        let prog = parse_program(&text).unwrap_or_else(|e| panic!("case {case}:\n{text}\n{e}"));
        let n_out = prog.outputs.len();
        assert!((1..=3).contains(&n_out), "case {case}: {n_out} outputs");

        let trace = trace_program(&prog, &[vec![synth::noise_rgb(h, w, case)]]).unwrap();
        let mut ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
        ir.set_outputs_from(&prog).unwrap();

        let cfg = Config {
            artifacts_dir: tmp.path().to_path_buf(),
            cpu_only: true,
            threads: 1 + rng.below(3),
            tokens: 1 + rng.below(3),
            ..Default::default()
        };
        let built = build(&ir, &db, &rt, &registry, &cfg).unwrap();
        built.plan.validate_dag().unwrap();
        built
            .check_output_matches(&prog)
            .unwrap_or_else(|e| panic!("case {case}:\n{text}\n{e}"));
        assert_eq!(built.terminal_steps.len(), n_out, "case {case}:\n{text}");

        let interp = Interpreter::new(prog, dispatch.clone());
        for fseed in 0..2 {
            let frame = synth::noise_rgb(h, w, 500 + case * 10 + fseed);
            let want = interp.run(&[frame.clone()]).unwrap();
            let got = built.process_one_all(frame).unwrap();
            assert_eq!(got, want, "case {case} seed {fseed}:\n{text}");
        }
        let frames: Vec<Mat> = (0..3).map(|s| synth::noise_rgb(h, w, 900 + s)).collect();
        let (bundles, _) = built.run_all(frames.clone()).unwrap();
        for (i, f) in frames.into_iter().enumerate() {
            assert_eq!(bundles[i], interp.run(&[f]).unwrap(), "case {case} frame {i}:\n{text}");
        }
    }
}
