//! Golden corpus gate: every `.courier` program under `examples/courier/`
//! must parse and lower (or fail with its annotated typed error).
//!
//! Each corpus file's first line is an annotation comment:
//!
//! ```text
//! # expect: ok           — parses, traces, lowers and plans hermetically
//! # expect: parse-error  — parse_program returns CourierError::Parse
//! ```
//!
//! This is the grammar's compatibility contract in file form: the flat
//! subset (`corner_harris`, `edge`, `harris_dag`) must stay parseable
//! forever, the Courier-Script fixtures pin `const`/`let`/multi-`output`
//! lowering, and the error fixtures pin the typed diagnostics.

use std::path::PathBuf;

use courier::app::{parse_program, synth_frames};
use courier::config::Config;
use courier::hwdb::HwDatabase;
use courier::ir::Ir;
use courier::pipeline::plan_pipeline;
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph};
use courier::util::testing::empty_hwdb_dir;
use courier::CourierError;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("courier")
}

/// (file name, source text, annotated expectation) for every corpus file.
fn corpus() -> Vec<(String, String, String)> {
    let mut files: Vec<(String, String, String)> = std::fs::read_dir(corpus_dir())
        .expect("examples/courier/ exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "courier"))
        .map(|p| {
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            let text = std::fs::read_to_string(&p).unwrap();
            let expect = text
                .lines()
                .next()
                .and_then(|l| l.strip_prefix("# expect:"))
                .unwrap_or_else(|| panic!("{name}: first line must be '# expect: <verdict>'"))
                .trim()
                .to_string();
            (name, text, expect)
        })
        .collect();
    files.sort();
    assert!(files.len() >= 9, "corpus lost files: {} found", files.len());
    files
}

#[test]
fn every_corpus_program_parses_and_lowers_or_fails_as_annotated() {
    let tmp = empty_hwdb_dir("golden-corpus").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let registry = Registry::standard();
    let cfg = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };

    for (name, text, expect) in corpus() {
        match expect.as_str() {
            "ok" => {
                let prog = parse_program(&text)
                    .unwrap_or_else(|e| panic!("{name}: annotated ok but failed to parse: {e}"));
                let trace = trace_program(&prog, &synth_frames(&prog, 1))
                    .unwrap_or_else(|e| panic!("{name}: trace failed: {e}"));
                let mut ir = Ir::from_graph(&CallGraph::from_trace(&trace))
                    .unwrap_or_else(|e| panic!("{name}: lowering failed: {e}"));
                ir.set_outputs_from(&prog)
                    .unwrap_or_else(|e| panic!("{name}: output binding failed: {e}"));
                let plan = plan_pipeline(&ir, &db, &registry, &cfg, None)
                    .unwrap_or_else(|e| panic!("{name}: planning failed: {e}"));
                plan.validate_dag()
                    .unwrap_or_else(|e| panic!("{name}: illegal plan: {e}"));
                assert_eq!(
                    plan.terminal_steps().len(),
                    prog.outputs.len(),
                    "{name}: plan egresses every declared output"
                );
            }
            "parse-error" => match parse_program(&text) {
                Err(CourierError::Parse { .. }) => {}
                Err(other) => panic!("{name}: wrong error type: {other}"),
                Ok(_) => panic!("{name}: annotated parse-error but parsed cleanly"),
            },
            other => panic!("{name}: unknown expectation {other:?}"),
        }
    }
}

#[test]
fn builtin_demos_are_mirrored_in_the_corpus() {
    // the in-crate demo constructors and the on-disk corpus must not
    // drift: the corpus copies parse to the same program structure
    let pairs: [(&str, courier::app::Program); 3] = [
        ("morphology.courier", courier::app::morphology_demo(24, 32)),
        ("corner_harris.courier", courier::app::corner_harris_demo(48, 64)),
        ("pyramid.courier", courier::app::gaussian_pyramid_demo(24, 32)),
    ];
    for (file, want) in pairs {
        let text = std::fs::read_to_string(corpus_dir().join(file)).unwrap();
        let got = parse_program(&text).unwrap();
        assert_eq!(got, want, "{file} drifted from its builtin constructor");
    }
}
