//! Kernel parity property suite: every optimized path in
//! `swlib::imgproc` — interior/border split stencils, the fused Sobel
//! pair, the scratch-reusing Harris, the fused gray→response mega-kernel,
//! pooled and in-place variants — must match the naive reference
//! (`imgproc::reference`) **bit-for-bit**; the separable two-pass
//! Gaussian may differ by reassociation only (~1 ULP), pinned with a
//! tight relative tolerance.  Shapes sweep the degenerate corners (1×1,
//! 1×N, N×1) plus randomized sizes.

use courier::image::{synth, Mat};
use courier::pipeline::BufferPool;
use courier::swlib::imgproc::{self, reference, HARRIS_K};
use courier::util::rng::Rng;

/// The shape sweep: degenerate corners + a few fixed + randomized sizes.
fn shapes() -> Vec<(usize, usize)> {
    let mut s = vec![
        (1, 1),
        (1, 2),
        (2, 1),
        (1, 9),
        (9, 1),
        (2, 2),
        (2, 5),
        (5, 2),
        (3, 3),
        (7, 13),
        (16, 16),
    ];
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..6 {
        s.push((1 + rng.below(24), 1 + rng.below(24)));
    }
    s
}

fn gray(h: usize, w: usize, seed: u64) -> Mat {
    synth::noise_gray(h, w, seed)
}

#[test]
fn unary_stencils_match_reference_bit_for_bit() {
    for (h, w) in shapes() {
        for seed in 0..2u64 {
            let img = gray(h, w, seed);
            let cases: Vec<(&str, Mat, Mat)> = vec![
                (
                    "sobel_dx",
                    imgproc::sobel(&img, 1, 0).unwrap(),
                    reference::sobel(&img, 1, 0).unwrap(),
                ),
                (
                    "sobel_dy",
                    imgproc::sobel(&img, 0, 1).unwrap(),
                    reference::sobel(&img, 0, 1).unwrap(),
                ),
                (
                    "box_norm",
                    imgproc::box_filter(&img, true).unwrap(),
                    reference::box_filter(&img, true).unwrap(),
                ),
                (
                    "box_raw",
                    imgproc::box_filter(&img, false).unwrap(),
                    reference::box_filter(&img, false).unwrap(),
                ),
                (
                    "laplacian",
                    imgproc::laplacian(&img).unwrap(),
                    reference::laplacian(&img).unwrap(),
                ),
                (
                    "scharr",
                    imgproc::scharr(&img).unwrap(),
                    reference::scharr(&img).unwrap(),
                ),
                (
                    "median",
                    imgproc::median_blur(&img).unwrap(),
                    reference::median_blur(&img).unwrap(),
                ),
                (
                    "erode",
                    imgproc::erode(&img).unwrap(),
                    reference::erode(&img).unwrap(),
                ),
                (
                    "dilate",
                    imgproc::dilate(&img).unwrap(),
                    reference::dilate(&img).unwrap(),
                ),
                (
                    "harris",
                    imgproc::corner_harris(&img, HARRIS_K).unwrap(),
                    reference::corner_harris(&img, HARRIS_K).unwrap(),
                ),
            ];
            for (name, fast, naive) in cases {
                assert_eq!(fast, naive, "{name} diverges at ({h}, {w}) seed {seed}");
            }
        }
    }
}

#[test]
fn separable_gaussian_within_one_ulp_of_reference() {
    for (h, w) in shapes() {
        let img = gray(h, w, 11);
        let sep = imgproc::gaussian_blur(&img).unwrap();
        let full = reference::gaussian_blur(&img).unwrap();
        // values are O(255): 1e-6 relative ~= 1 ULP at that magnitude
        assert!(
            sep.allclose(&full, 1e-6, 1e-4),
            "gaussian diverges at ({h}, {w}): max diff {}",
            sep.max_abs_diff(&full)
        );
    }
}

#[test]
fn fused_sobel_pair_matches_split_kernels() {
    for (h, w) in shapes() {
        let img = gray(h, w, 23);
        let mut dx = Mat::zeros(img.shape());
        let mut dy = Mat::zeros(img.shape());
        imgproc::sobel_xy_into(&img, &mut dx, &mut dy).unwrap();
        assert_eq!(dx, reference::sobel(&img, 1, 0).unwrap(), "dx ({h}, {w})");
        assert_eq!(dy, reference::sobel(&img, 0, 1).unwrap(), "dy ({h}, {w})");
    }
}

#[test]
fn harris_response_and_elementwise_match_reference() {
    for (h, w) in shapes() {
        let img = gray(h, w, 31);
        let ix = imgproc::sobel(&img, 1, 0).unwrap();
        let iy = imgproc::sobel(&img, 0, 1).unwrap();
        assert_eq!(
            imgproc::harris_response(&ix, &iy, HARRIS_K).unwrap(),
            reference::harris_response(&ix, &iy, HARRIS_K).unwrap(),
            "harris_response ({h}, {w})"
        );
        assert_eq!(
            imgproc::normalize(&img, 0.0, 255.0).unwrap(),
            reference::normalize(&img, 0.0, 255.0).unwrap()
        );
        assert_eq!(
            imgproc::convert_scale_abs(&img, 1.0, 0.0).unwrap(),
            reference::convert_scale_abs(&img, 1.0, 0.0).unwrap()
        );
        assert_eq!(
            imgproc::threshold(&img, 127.0, 255.0).unwrap(),
            reference::threshold(&img, 127.0, 255.0).unwrap()
        );
    }
}

#[test]
fn pooled_variants_match_plain_across_shapes() {
    let pool = BufferPool::new();
    for (h, w) in shapes() {
        let img = gray(h, w, 41);
        // run every pooled kernel twice so the second pass consumes
        // recycled (dirty) storage — any cell the kernel forgets to
        // overwrite shows up as a mismatch
        for pass in 0..2 {
            let ctx = format!("({h}, {w}) pass {pass}");
            let out = imgproc::corner_harris_pooled(&img, HARRIS_K, &pool).unwrap();
            assert_eq!(out, reference::corner_harris(&img, HARRIS_K).unwrap(), "{ctx}");
            pool.release(out);
            let ix = imgproc::sobel(&img, 1, 0).unwrap();
            let iy = imgproc::sobel(&img, 0, 1).unwrap();
            let resp = imgproc::harris_response_pooled(&ix, &iy, HARRIS_K, &pool).unwrap();
            assert_eq!(resp, reference::harris_response(&ix, &iy, HARRIS_K).unwrap(), "{ctx}");
            pool.release(resp);
        }
    }
}

#[test]
fn fused_gray_response_pipeline_matches_chain_across_shapes() {
    let pool = BufferPool::new();
    for (h, w) in shapes() {
        let rgb = synth::noise_rgb(h, w, 51);
        let gray = imgproc::cvt_color(&rgb).unwrap();
        let want = reference::corner_harris(&gray, HARRIS_K).unwrap();
        assert_eq!(imgproc::harris_pipeline(&rgb, HARRIS_K).unwrap(), want, "({h}, {w})");
        let pooled = imgproc::harris_pipeline_pooled(&rgb, HARRIS_K, &pool).unwrap();
        assert_eq!(pooled, want, "pooled ({h}, {w})");
        pool.release(pooled);
    }
}

#[test]
fn banded_and_simd_interiors_match_reference_bit_for_bit() {
    // The row-band shards and the vectorized interiors must be
    // unobservable: only the destination is partitioned (sources are
    // shared immutably, halo rows are free reads) and the vector ops are
    // lanewise in the scalar evaluation order, so every combination of
    // band count × SIMD toggle is bit-identical to the naive reference.
    // Band counts deliberately straddle the heights in the shape sweep
    // (bands > rows clamps), and band boundaries land mid-stencil.
    use courier::swlib::banding::{force_simd, set_bands};
    for &bands in &[1usize, 2, 3, 8] {
        for &simd in &[false, true] {
            let _b = set_bands(bands);
            let _s = force_simd(simd);
            for (h, w) in shapes() {
                let img = gray(h, w, 7);
                let ctx = format!("({h}, {w}) bands={bands} simd={simd}");
                assert_eq!(
                    imgproc::sobel(&img, 1, 0).unwrap(),
                    reference::sobel(&img, 1, 0).unwrap(),
                    "sobel dx {ctx}"
                );
                assert_eq!(
                    imgproc::sobel(&img, 0, 1).unwrap(),
                    reference::sobel(&img, 0, 1).unwrap(),
                    "sobel dy {ctx}"
                );
                let mut dx = Mat::zeros(img.shape());
                let mut dy = Mat::zeros(img.shape());
                imgproc::sobel_xy_into(&img, &mut dx, &mut dy).unwrap();
                assert_eq!(dx, reference::sobel(&img, 1, 0).unwrap(), "pair dx {ctx}");
                assert_eq!(dy, reference::sobel(&img, 0, 1).unwrap(), "pair dy {ctx}");
                assert_eq!(
                    imgproc::box_filter(&img, true).unwrap(),
                    reference::box_filter(&img, true).unwrap(),
                    "box {ctx}"
                );
                assert_eq!(
                    imgproc::laplacian(&img).unwrap(),
                    reference::laplacian(&img).unwrap(),
                    "laplacian {ctx}"
                );
                assert_eq!(
                    imgproc::scharr(&img).unwrap(),
                    reference::scharr(&img).unwrap(),
                    "scharr {ctx}"
                );
                assert_eq!(
                    imgproc::median_blur(&img).unwrap(),
                    reference::median_blur(&img).unwrap(),
                    "median {ctx}"
                );
                assert_eq!(
                    imgproc::erode(&img).unwrap(),
                    reference::erode(&img).unwrap(),
                    "erode {ctx}"
                );
                assert_eq!(
                    imgproc::dilate(&img).unwrap(),
                    reference::dilate(&img).unwrap(),
                    "dilate {ctx}"
                );
                assert_eq!(
                    imgproc::corner_harris(&img, HARRIS_K).unwrap(),
                    reference::corner_harris(&img, HARRIS_K).unwrap(),
                    "harris {ctx}"
                );
                let rgb = synth::noise_rgb(h, w, 7);
                let cvt_want = {
                    // scalar, unsharded baseline (guards nest + restore)
                    let _b0 = set_bands(1);
                    let _s0 = force_simd(false);
                    imgproc::cvt_color(&rgb).unwrap()
                };
                assert_eq!(imgproc::cvt_color(&rgb).unwrap(), cvt_want, "cvt {ctx}");
                // separable Gaussian: banding/SIMD may not add ANY error
                // beyond the reassociation the two-pass form already has
                let sep = imgproc::gaussian_blur(&img).unwrap();
                let full = reference::gaussian_blur(&img).unwrap();
                assert!(
                    sep.allclose(&full, 1e-6, 1e-4),
                    "gaussian {ctx}: max diff {}",
                    sep.max_abs_diff(&full)
                );
            }
        }
    }
}

#[test]
fn banded_gaussian_is_bitwise_stable_across_band_counts() {
    // the two-pass Gaussian must produce the SAME bits whatever the band
    // count (halo rows of the h-pass are recomputed identically by
    // neighbouring bands), so deployments can retune bands without
    // golden outputs shifting
    use courier::swlib::banding::set_bands;
    for (h, w) in shapes() {
        let img = gray(h, w, 13);
        let baseline = {
            let _b = set_bands(1);
            imgproc::gaussian_blur(&img).unwrap()
        };
        for &bands in &[2usize, 3, 5, 8] {
            let _b = set_bands(bands);
            assert_eq!(
                imgproc::gaussian_blur(&img).unwrap(),
                baseline,
                "({h}, {w}) bands={bands}"
            );
        }
    }
}

#[test]
fn into_variants_validate_out_shape() {
    let img = gray(6, 6, 1);
    let mut wrong = Mat::zeros(&[5, 6]);
    assert!(imgproc::sobel_into(&img, 1, 0, &mut wrong).is_err());
    assert!(imgproc::cvt_color_into(&synth::noise_rgb(4, 4, 0), &mut wrong).is_err());
    let mut tmp = Mat::zeros(&[6, 6]);
    assert!(imgproc::gaussian_blur_into(&img, &mut tmp, &mut wrong).is_err());
}
