//! Stress: the trace sink under the token runtime's adversarial jitter
//! schedule (the same shape as `tbb_stress.rs`).
//!
//! The claim being pinned: a merged `snapshot_events()` view is
//! **loss-free** (capacity permitting, `dropped() == 0`) and
//! **frame-consistent** — every frame appears with exactly one stage
//! span per stage, queue-wait never exceeds the span's own timeline
//! position, and the merged view is chronological.  Worker threads race
//! on the sink's shards for the whole run; any torn or misattributed
//! record shows up as a duplicated or missing `(frame, stage)` pair.
//!
//! All randomness is seeded (`util::rng::Rng`); no wall-clock assertions.

use std::collections::HashMap;
use std::sync::Arc;

use courier::image::Mat;
use courier::obs::{EventKind, TraceSink};
use courier::pipeline::{FilterMode, FnFilter, StageFilter, TokenPipeline};
use courier::util::rng::Rng;

/// Deterministic per-(token, stage) jitter in [0, max_us).
fn jitter_us(seed: u64, token: u64, stage: u64, max_us: u64) -> u64 {
    Rng::new(seed ^ (token << 8) ^ stage).next_u64() % max_us
}

fn jitter_filter(mode: FilterMode, stage: u64, seed: u64, max_us: u64) -> Box<dyn StageFilter> {
    Box::new(FnFilter {
        mode,
        label: format!("jitter{stage}"),
        f: move |mut m: Mat| {
            let token = m.at2(0, 0).floor() as u64;
            let us = jitter_us(seed, token, stage, max_us);
            if us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(us));
            }
            for v in m.as_mut_slice() {
                *v += 0.125;
            }
            Ok(m)
        },
    })
}

#[test]
fn merged_spans_are_loss_free_and_frame_consistent_under_stress() {
    let (frames, threads, tokens, seed, max_us) = (2_000usize, 4, 3, 0xC0FFEE_u64, 24);
    let stages = 4usize;
    // capacity sized so even a maximally skewed shard holds every span
    let sink = Arc::new(TraceSink::with_capacity(frames * stages));
    let pipe = TokenPipeline::new(
        vec![
            jitter_filter(FilterMode::SerialInOrder, 0, seed, max_us / 4),
            jitter_filter(FilterMode::Parallel, 1, seed, max_us),
            jitter_filter(FilterMode::Parallel, 2, seed.rotate_left(17), max_us),
            jitter_filter(FilterMode::SerialInOrder, 3, seed, max_us / 4),
        ],
        threads,
        tokens,
    )
    .unwrap()
    .with_sink(sink.clone());

    let inputs: Vec<Mat> = (0..frames).map(|i| Mat::full(&[1, 1], i as f32)).collect();
    let (out, stats) = pipe.run(inputs).unwrap();
    assert_eq!(out.len(), frames);

    // loss-free: nothing overwritten, one record per runtime span
    assert_eq!(sink.dropped(), 0, "sink capacity must hold the whole run");
    assert_eq!(sink.recorded(), (frames * stages) as u64);
    assert_eq!(stats.spans.len(), frames * stages);

    let events = sink.snapshot_events();
    assert_eq!(events.len(), frames * stages);

    // frame-consistent: every frame carries exactly one span per stage
    let mut per_frame: HashMap<u64, Vec<u32>> = HashMap::new();
    for e in &events {
        assert_eq!(e.kind, EventKind::StageSpan);
        assert!(
            e.arg <= e.ts_ns,
            "queue wait {} precedes the epoch (span starts at {})",
            e.arg,
            e.ts_ns
        );
        per_frame.entry(e.frame).or_default().push(e.stage);
    }
    assert_eq!(per_frame.len(), frames, "every frame must appear in the merged view");
    for (frame, mut chain) in per_frame {
        chain.sort_unstable();
        assert_eq!(
            chain,
            (0..stages as u32).collect::<Vec<_>>(),
            "frame {frame} has a broken stage chain"
        );
    }

    // the merged snapshot is chronological across shards
    for w in events.windows(2) {
        assert!(w[0].ts_ns <= w[1].ts_ns, "snapshot must merge shards in time order");
    }
}
