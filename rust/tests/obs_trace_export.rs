//! Integration: the observability exports of a serving run.
//!
//! A hermetic (CPU-only) `harris_dag` serve produces, without any
//! opt-in flags, (a) a Chrome trace-event document that roundtrips
//! through our own JSON parser with the schema Perfetto expects, and
//! (b) a metrics snapshot whose critical-path attribution decomposes
//! the measured end-to-end frame latency into ingress/fabric/queue/
//! service buckets that — together with the explicit residual — sum
//! back to the measured number, with the bottleneck stage named.

use courier::app::harris_dag_demo;
use courier::config::Config;
use courier::image::{synth, Mat};
use courier::serve::{Server, SessionSpec};
use courier::util::json::{parse, Json};
use courier::util::testing::{empty_hwdb_dir, TempDir};

const FRAMES: usize = 6;

fn served_server() -> (Server, TempDir) {
    let tmp = empty_hwdb_dir("obs-export").unwrap();
    let mut cfg = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };
    cfg.serve.workers = 2;
    cfg.serve.queue_depth = 4;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(harris_dag_demo(24, 32))).unwrap();
    let frames: Vec<Mat> = (0..FRAMES).map(|s| synth::noise_rgb(24, 32, s as u64)).collect();
    let outs = session.run_window(frames).unwrap();
    assert_eq!(outs.len(), FRAMES);
    (server, tmp)
}

#[test]
fn chrome_trace_export_has_the_perfetto_schema() {
    let (server, _tmp) = served_server();
    let text = server.chrome_trace().to_string_pretty();
    let doc = parse(&text).expect("trace export must be valid JSON");
    assert_eq!(doc.req("displayTimeUnit").unwrap().as_str().unwrap(), "ms");

    let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "a served run must leave trace events behind");
    let (mut spans, mut metas, mut instants) = (0usize, 0usize, 0usize);
    for e in events {
        // every event carries the fields the trace UI keys on
        assert!(e.req("name").unwrap().as_str().is_ok());
        assert!(e.req("pid").unwrap().as_u64().is_ok());
        assert!(e.req("tid").unwrap().as_u64().is_ok());
        match e.req("ph").unwrap().as_str().unwrap() {
            "X" => {
                spans += 1;
                assert!(e.req("ts").unwrap().as_f64().is_ok());
                assert!(e.req("dur").unwrap().as_f64().is_ok());
            }
            "M" => metas += 1,
            "i" => instants += 1,
            other => panic!("unexpected trace phase {other:?}"),
        }
    }
    assert!(spans >= FRAMES, "at least one complete span per served frame");
    assert!(metas > 0, "process_name metadata names the session lanes");
    assert!(instants >= 2 * FRAMES, "ingress + egress instants per frame");

    server.shutdown();
}

#[test]
fn metrics_snapshot_attribution_sums_to_measured_latency() {
    let (server, _tmp) = served_server();
    let snap = server.metrics_snapshot();

    // non-zero frame counts in the registry section
    let frames_total = snap
        .req("serve")
        .unwrap()
        .req("server")
        .unwrap()
        .req("frames")
        .unwrap()
        .req("total")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(frames_total >= FRAMES as u64, "server throughput saw {frames_total} frames");

    // exactly one cached plan -> exactly one attribution entry
    let attrib = match snap.req("attribution").unwrap() {
        Json::Obj(pairs) => pairs,
        other => panic!("attribution must be an object, got {other:?}"),
    };
    assert_eq!(attrib.len(), 1, "one cached plan, one attribution entry");
    let (plan_key, a) = &attrib[0];
    assert!(plan_key.contains("24x32"), "entry is keyed by plan ({plan_key})");

    assert!(a.req("frames").unwrap().as_u64().unwrap() > 0);
    let e2e = a.req("e2e_ms_per_frame").unwrap().as_f64().unwrap();
    let attributed = a.req("attributed_ms_per_frame").unwrap().as_f64().unwrap();
    let residual = a.req("residual_ms_per_frame").unwrap().as_f64().unwrap();
    assert!(e2e > 0.0, "served frames take measurable time");
    assert!(
        (attributed + residual - e2e).abs() < 1e-6,
        "buckets + residual must reconstruct e2e: {attributed} + {residual} vs {e2e}"
    );

    // the per-stage table has real spans and a named bottleneck
    let stages = a.req("stages").unwrap().as_arr().unwrap();
    assert!(!stages.is_empty());
    let folded: u64 = stages
        .iter()
        .map(|s| s.req("spans").unwrap().as_u64().unwrap())
        .sum();
    assert!(folded > 0, "stage spans folded into the attribution");
    let bottleneck = a.req("bottleneck").unwrap().as_str().unwrap().to_string();
    assert!(
        stages.iter().any(|s| s.req("name").unwrap().as_str().unwrap() == bottleneck),
        "bottleneck {bottleneck:?} names one of the stages"
    );

    server.shutdown();
}
