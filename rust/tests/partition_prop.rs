//! Property suite for the planner/partitioner: for randomized linear *and
//! DAG-shaped* call graphs, every produced plan
//!
//! 1. is a contiguous, order-preserving partition covering every IR
//!    function exactly once,
//! 2. places hardware tasks only on modules that exist (and are enabled)
//!    in the hardware-database manifest with a matching shape variant,
//! 3. keeps the paper's filter modes: serial head/tail, parallel middles,
//! 4. is DAG-legal: no dependency edge points backwards across a stage
//!    cut, fork-join branches cover each stage's tasks exactly once, and
//!    linear chains reproduce the pre-DAG partitions bit-for-bit.
//!
//! Randomness comes from the crate's tiny seeded PRNG (`util::rng::Rng`)
//! through the `forall` helper — no new dependencies, reproducible seeds.

use std::path::PathBuf;

use courier::app::parse_program;
use courier::config::{Config, PartitionPolicy};
use courier::hwdb::HwDatabase;
use courier::image::synth;
use courier::ir::{Ir, IrFunc, Placement};
use courier::pipeline::{partition, plan_pipeline, respects_dag, TaskKind};
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph, DataNode};
use courier::util::rng::Rng;
use courier::util::testing::{forall, TempDir};

/// Symbols the random chains draw from.  All exist in the standard CPU
/// registry; the manifest below gives a hardware module to some of them
/// (one enabled per shape, one disabled) so random chains mix placements.
const POOL: &[&str] = &[
    "cv::cvtColor",
    "cv::Sobel",
    "cv::GaussianBlur",
    "cv::dilate",
    "cv::erode",
    "cv::normalize",
    "cv::medianBlur",
];

/// Shapes the random chains draw from (the manifest only covers some).
const SHAPES: &[&[usize]] = &[&[16, 16], &[32, 32], &[16, 16, 3], &[8, 24]];

fn manifest_dir() -> (TempDir, PathBuf) {
    let tmp = TempDir::new("partition-prop").unwrap();
    let manifest = r#"{
        "version": 1,
        "fabric_clock_mhz": 157.0,
        "modules": [
            {
                "name": "hls_sobel",
                "library_symbol": "cv::Sobel",
                "enabled": true,
                "kind": "image1",
                "variants": [{
                    "size": [16, 16],
                    "inputs": [{"shape": [16, 16], "dtype": "f32"}],
                    "outputs": [{"shape": [16, 16], "dtype": "f32"}],
                    "artifact": "hls_sobel__16x16.hlo.txt",
                    "est_flops": 4096.0,
                    "est_bytes": 2048.0,
                    "est_latency_cycles": 512
                }]
            },
            {
                "name": "hls_dilate",
                "library_symbol": "cv::dilate",
                "enabled": true,
                "kind": "image1",
                "variants": [{
                    "size": [32, 32],
                    "inputs": [{"shape": [32, 32], "dtype": "f32"}],
                    "outputs": [{"shape": [32, 32], "dtype": "f32"}],
                    "artifact": "hls_dilate__32x32.hlo.txt",
                    "est_flops": 16384.0,
                    "est_bytes": 8192.0,
                    "est_latency_cycles": 2048
                }]
            },
            {
                "name": "hls_normalize",
                "library_symbol": "cv::normalize",
                "enabled": false,
                "kind": "image1",
                "variants": [{
                    "size": [16, 16],
                    "inputs": [{"shape": [16, 16], "dtype": "f32"}],
                    "outputs": [{"shape": [16, 16], "dtype": "f32"}],
                    "artifact": "hls_normalize__16x16.hlo.txt",
                    "est_flops": 1024.0,
                    "est_bytes": 2048.0,
                    "est_latency_cycles": 256
                }]
            }
        ]
    }"#;
    std::fs::write(tmp.path().join("manifest.json"), manifest).unwrap();
    let dir = tmp.path().to_path_buf();
    (tmp, dir)
}

/// A randomized linear call graph: chain length, symbols, per-function
/// input shapes and traced times all drawn from the seeded PRNG.
fn random_ir(rng: &mut Rng) -> Ir {
    let n = 1 + rng.below(8);
    let funcs: Vec<IrFunc> = (0..n)
        .map(|i| IrFunc {
            step: i,
            symbol: POOL[rng.below(POOL.len())].to_string(),
            covers: vec![i],
            mean_ns: rng.range_u64(1, 5_000_000),
            placement: Placement::Auto,
        })
        .collect();
    let data: Vec<DataNode> = (0..n)
        .map(|i| {
            let shape = SHAPES[rng.below(SHAPES.len())].to_vec();
            let bytes = shape.iter().product::<usize>() * 4;
            DataNode {
                id: i,
                shape,
                bytes,
                producer: if i == 0 { None } else { Some(i - 1) },
                consumers: vec![i],
            }
        })
        .collect();
    Ir { program: "prop".into(), frames: 1, funcs, data }
}

fn random_cfg(rng: &mut Rng, artifacts_dir: PathBuf) -> Config {
    let policy = [
        PartitionPolicy::Paper,
        PartitionPolicy::Optimal,
        PartitionPolicy::PerFunction,
        PartitionPolicy::Single,
    ][rng.below(4)];
    Config {
        artifacts_dir,
        threads: 1 + rng.below(6),
        tokens: 1 + rng.below(8),
        policy,
        ..Default::default()
    }
}

#[test]
fn plans_partition_contiguously_and_cover_every_function_once() {
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        200,
        |rng| (random_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let plan = plan_pipeline(ir, &db, &registry, cfg, None).expect("plannable chain");
            // contiguous cover: concatenated task covers == 0..n exactly
            let covered: Vec<usize> = plan
                .stages
                .iter()
                .flat_map(|s| &s.tasks)
                .flat_map(|t| t.covers.iter().copied())
                .collect();
            let expect: Vec<usize> = (0..ir.funcs.len()).collect();
            if covered != expect {
                return false;
            }
            // no empty stages, indices sequential
            plan.stages
                .iter()
                .enumerate()
                .all(|(i, s)| !s.tasks.is_empty() && s.index == i)
        },
    );
}

#[test]
fn hardware_stages_only_use_enabled_manifest_modules() {
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        200,
        |rng| (random_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let plan = plan_pipeline(ir, &db, &registry, cfg, None).expect("plannable chain");
            let shapes: Vec<Vec<usize>> =
                ir.data.iter().map(|d| d.shape.clone()).collect();
            let mut task_idx = 0usize;
            for stage in &plan.stages {
                for task in &stage.tasks {
                    if let TaskKind::Hw { module, .. } = &task.kind {
                        // the placed module must exist, be enabled, match
                        // the symbol, and carry a variant for this shape
                        let entry = match db.module_by_name(module) {
                            Some(e) => e,
                            None => return false,
                        };
                        if !entry.enabled || entry.library_symbol != task.symbol {
                            return false;
                        }
                        let shape = &shapes[task_idx];
                        if db.lookup(&task.symbol, &[shape.as_slice()]).is_none() {
                            return false;
                        }
                    }
                    task_idx += 1;
                }
            }
            true
        },
    );
}

#[test]
fn serial_head_tail_parallel_middles_and_hw_placement_happens() {
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    let mut saw_hw = false;
    let mut saw_multi_stage = false;
    forall(
        200,
        |rng| (random_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let plan = plan_pipeline(ir, &db, &registry, cfg, None).expect("plannable chain");
            let n = plan.stages.len();
            saw_hw |= plan.placement_counts().0 > 0;
            saw_multi_stage |= n > 2;
            if !plan.stages[0].serial || !plan.stages[n - 1].serial {
                return false;
            }
            n < 2 || plan.stages[1..n - 1].iter().all(|s| !s.serial)
        },
    );
    // the generators must actually exercise both interesting regimes
    assert!(saw_hw, "random chains never hit the hardware database");
    assert!(saw_multi_stage, "random chains never produced a multi-stage plan");
}

/// A randomized DAG-shaped call graph over a fixed single-channel shape:
/// step 0 consumes the external input; every later step consumes 1–2
/// earlier outputs (topological by construction).  One data node per
/// dependency edge, like the tracer produces.
fn random_dag_ir(rng: &mut Rng) -> Ir {
    let n = 2 + rng.below(7);
    let shape = vec![16usize, 16];
    let funcs: Vec<IrFunc> = (0..n)
        .map(|i| IrFunc {
            step: i,
            symbol: POOL[rng.below(POOL.len())].to_string(),
            covers: vec![i],
            mean_ns: rng.range_u64(1, 5_000_000),
            placement: Placement::Auto,
        })
        .collect();
    let bytes = shape.iter().product::<usize>() * 4;
    let mut data: Vec<DataNode> = vec![DataNode {
        id: 0,
        shape: shape.clone(),
        bytes,
        producer: None,
        consumers: vec![0],
    }];
    for i in 1..n {
        let parents = 1 + rng.below(2.min(i));
        let mut used: Vec<usize> = Vec::new();
        for _ in 0..parents {
            let p = rng.below(i);
            if used.contains(&p) {
                continue;
            }
            used.push(p);
            data.push(DataNode {
                id: data.len(),
                shape: shape.clone(),
                bytes,
                producer: Some(p),
                consumers: vec![i],
            });
        }
    }
    Ir { program: "dagprop".into(), frames: 1, funcs, data }
}

#[test]
fn dag_plans_are_convex_and_fork_join_branches_cover_each_stage_once() {
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        200,
        |rng| (random_dag_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let plan = plan_pipeline(ir, &db, &registry, cfg, None).expect("plannable DAG");
            if plan.validate_dag().is_err() {
                return false;
            }
            // stage cuts are convex: no dependency edge points backwards
            let mut groups: Vec<std::ops::Range<usize>> = Vec::new();
            let mut start = 0usize;
            for s in &plan.stages {
                groups.push(start..start + s.tasks.len());
                start += s.tasks.len();
            }
            let flat: Vec<usize> = plan.flat_covers();
            let task_of = |step: usize| flat.iter().position(|&s| s == step);
            let func_edges: Vec<(usize, usize)> = plan
                .effective_edges()
                .iter()
                .filter_map(|(p, c)| match p {
                    Some(p) => match (task_of(*p), task_of(*c)) {
                        (Some(a), Some(b)) if a != b => Some((a, b)),
                        _ => None,
                    },
                    None => None,
                })
                .collect();
            if !respects_dag(&groups, &func_edges) {
                return false;
            }
            // fork-join branches cover each stage's tasks exactly once
            let edges = plan.effective_edges();
            for s in &plan.stages {
                let mut covered: Vec<usize> =
                    s.branches(&edges).into_iter().flatten().collect();
                covered.sort_unstable();
                if covered != (0..s.tasks.len()).collect::<Vec<_>>() {
                    return false;
                }
            }
            // every function covered exactly once, in order
            let expect: Vec<usize> = (0..ir.funcs.len()).collect();
            flat == expect
        },
    );
}

#[test]
fn linear_chains_reproduce_the_pre_dag_partitions_bit_for_bit() {
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        200,
        |rng| (random_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let plan = plan_pipeline(ir, &db, &registry, cfg, None).expect("plannable chain");
            // chain plans carry no explicit edges: serialized form is the
            // pre-DAG format, byte for byte
            if !plan.edges.is_empty() || !plan.is_chain() {
                return false;
            }
            if plan.to_json().contains("\"edges\"") {
                return false;
            }
            // the stage grouping equals the edge-blind partition exactly
            let times: Vec<u64> = plan
                .stages
                .iter()
                .flat_map(|s| &s.tasks)
                .map(|t| t.est_ns)
                .collect();
            let expect = partition(&times, cfg.threads, cfg.policy);
            let mut got: Vec<std::ops::Range<usize>> = Vec::new();
            let mut start = 0usize;
            for s in &plan.stages {
                got.push(start..start + s.tasks.len());
                start += s.tasks.len();
            }
            got == expect
        },
    );
}

#[test]
fn search_never_proposes_a_dag_illegal_boundary_move() {
    // randomized DAG seeds through the tuner's whole search: every scored
    // candidate (policy sweeps, boundary shifts, fusions, queue ladder)
    // must stay DAG-legal and carry the seed's edge set unchanged
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        25,
        |rng| (random_dag_ir(rng), random_cfg(rng, dir.clone())),
        |(ir, cfg)| {
            let mut cfg = cfg.clone();
            cfg.tune.budget = 24;
            cfg.tune.sim_frames = 4;
            let seed = plan_pipeline(ir, &db, &registry, &cfg, None).expect("plannable DAG");
            let tasks: Vec<_> =
                seed.stages.iter().flat_map(|s| s.tasks.iter().cloned()).collect();
            let metrics = courier::metrics::TunerMetrics::default();
            let out = courier::tune::search(&seed, &tasks, &cfg, &metrics);
            out.candidates
                .iter()
                .all(|c| c.plan.validate_dag().is_ok() && c.plan.edges == seed.edges)
        },
    );
}

#[test]
fn golden_two_frame_harris_dag_trace_builds_cleanly() {
    // The fixture's second frame reuses frame 1's terminal output hash as
    // its *external input* hash — exactly the cross-frame collision that
    // used to fabricate a backwards step5 -> step0 edge.  With the
    // per-frame producer reset the trace lowers cleanly end to end.
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("tests/fixtures/harris_dag_two_frames.json"),
    )
    .unwrap();
    let trace = courier::trace::Trace::from_json(&text).unwrap();
    assert_eq!(trace.frames(), 2);

    let graph = courier::trace::CallGraph::from_trace(&trace);
    assert_eq!(graph.funcs.len(), 6);
    for f in &graph.funcs {
        assert_eq!(f.calls, 2, "{}: both frames must aggregate", f.symbol);
    }
    for d in &graph.data {
        if d.consumers.contains(&0) {
            assert_eq!(d.producer, None, "cross-frame edge fabricated: {d:?}");
        }
    }

    // graph -> IR -> plan, hermetically (empty hw database)
    let ir = Ir::from_graph(&graph).unwrap();
    assert!(!ir.is_chain());
    let tmp = courier::util::testing::empty_hwdb_dir("golden-dag").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let cfg = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };
    let plan = plan_pipeline(&ir, &db, &Registry::standard(), &cfg, None).unwrap();
    plan.validate_dag().unwrap();
    assert!(!plan.edges.is_empty(), "DAG plans carry explicit edges");
    assert!(plan.edges.contains(&(Some(0), 1)));
    assert!(plan.edges.contains(&(Some(0), 2)));
    assert!(plan.edges.contains(&(Some(1), 3)));
    assert!(plan.edges.contains(&(Some(2), 3)));
}

#[test]
fn calibration_moves_boundaries_but_preserves_invariants() {
    // a calibration layer that inflates one symbol must never break the
    // partition invariants, only move the cuts
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        100,
        |rng| (random_ir(rng), random_cfg(rng, dir.clone()), rng.below(POOL.len())),
        |(ir, cfg, hot)| {
            let mut cal = courier::hlo::CostCalibration::new();
            for d in &ir.data {
                for hw in [false, true] {
                    cal.set_factor(&courier::hlo::task_key(POOL[*hot], &d.shape, hw), 8.0);
                }
            }
            let plan =
                plan_pipeline(ir, &db, &registry, cfg, Some(&cal)).expect("plannable chain");
            let covered: usize =
                plan.stages.iter().map(|s| s.tasks.len()).sum();
            covered == ir.funcs.len() && plan.stages.iter().all(|s| !s.tasks.is_empty())
        },
    );
}

/// Random multi-branch Courier-Script source over the grayscale-safe
/// symbol pool (plus the shape-halving `cv::pyrDown` and a
/// scalar-bearing `cv::threshold`).  Branch tails become 1–3 `output`
/// declarations; each (parent, call) pair is sampled at most once so no
/// two steps alias under the content-hash tracer.
fn random_script(rng: &mut Rng, h: usize, w: usize) -> String {
    const GRAY_POOL: &[&str] = &[
        "cv::Sobel",
        "cv::GaussianBlur",
        "cv::dilate",
        "cv::erode",
        "cv::normalize",
        "cv::medianBlur",
    ];
    let mut text = format!(
        "program scriptPlanProp\n\
         input frame {h}x{w}x3\n\
         let gray = cv::cvtColor(frame)\n"
    );
    let mut names: Vec<String> = vec!["gray".into()];
    let mut seen: Vec<String> = Vec::new();
    let mut outputs: Vec<String> = Vec::new();
    for b in 0..1 + rng.below(3) {
        let mut cur = names[rng.below(names.len())].clone();
        for i in 0..1 + rng.below(3) {
            let name = format!("b{b}_{i}");
            let call = loop {
                let call = match rng.below(GRAY_POOL.len() + 2) {
                    c if c < GRAY_POOL.len() => format!("{}({cur})", GRAY_POOL[c]),
                    c if c == GRAY_POOL.len() => format!("cv::pyrDown({cur})"),
                    _ => format!("cv::threshold({cur}, 16, 240)"),
                };
                if !seen.contains(&call) {
                    break call;
                }
            };
            seen.push(call.clone());
            let kw = if rng.below(2) == 0 { "let" } else { "call" };
            text.push_str(&format!("{kw} {name} = {call}\n"));
            names.push(name.clone());
            cur = name;
        }
        outputs.push(cur);
    }
    for out in &outputs {
        text.push_str(&format!("output {out}\n"));
    }
    text
}

#[test]
fn random_courier_scripts_plan_legally_with_declared_outputs() {
    // Property 8: script-sourced IRs (fan-out, scalars, multi-output)
    // plan legally under every policy — contiguous cover, DAG-legal
    // cuts — and the plan egresses exactly the declared `output` steps
    // in declaration order.
    let (_tmp, dir) = manifest_dir();
    let db = HwDatabase::load(&dir).unwrap();
    let registry = Registry::standard();
    forall(
        60,
        |rng| {
            let shapes = [(16usize, 16usize), (24, 16), (32, 32)];
            let (h, w) = shapes[rng.below(shapes.len())];
            (random_script(rng, h, w), random_cfg(rng, dir.clone()))
        },
        |(text, cfg)| {
            let prog = parse_program(text).expect("generated script parses");
            let (_, shape) = &prog.inputs[0];
            let frame = synth::noise_rgb(shape[0], shape[1], 7);
            let trace = trace_program(&prog, &[vec![frame]]).expect("trace");
            let mut ir = Ir::from_graph(&CallGraph::from_trace(&trace)).expect("lower");
            ir.set_outputs_from(&prog).expect("bind outputs");
            let plan = plan_pipeline(&ir, &db, &registry, cfg, None).expect("plan");
            plan.validate_dag().expect("DAG-legal plan");
            let covered: usize = plan.stages.iter().map(|s| s.tasks.len()).sum();
            covered == ir.funcs.len()
                && plan.terminal_steps() == ir.terminal_steps()
                && ir.terminal_steps().len() == prog.outputs.len()
        },
    );
}
