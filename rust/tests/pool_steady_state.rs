//! The zero-allocation invariant: after a warm-up stream, the steady-state
//! frame path of a built pipeline draws every buffer from the pool —
//! `pool.stats().misses` stays flat while frames keep flowing.
//!
//! Hermetic (empty hardware database, CPU-only placement) and
//! deterministic: one worker thread, so acquire/release interleaving is a
//! fixed cycle and the assertion cannot flake on scheduling.

use courier::app::{corner_harris_demo, Interpreter, RegistryDispatch};
use courier::config::Config;
use courier::hwdb::HwDatabase;
use courier::image::{synth, Mat};
use courier::ir::Ir;
use courier::pipeline::{build, BuiltPipeline};
use courier::runtime::Runtime;
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph};
use courier::util::testing::empty_hwdb_dir;

fn hermetic_build(h: usize, w: usize, threads: usize, tokens: usize) -> BuiltPipeline {
    let tmp = empty_hwdb_dir("pool-steady").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let prog = corner_harris_demo(h, w);
    let trace = trace_program(&prog, &[vec![synth::noise_rgb(h, w, 0)]]).unwrap();
    let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
    let cfg = Config {
        artifacts_dir: tmp.path().to_path_buf(),
        cpu_only: true,
        threads,
        tokens,
        ..Default::default()
    };
    build(&ir, &db, &Runtime::cpu().unwrap(), &Registry::standard(), &cfg).unwrap()
}

fn frames(h: usize, w: usize, n: usize, base: u64) -> Vec<Mat> {
    (0..n).map(|i| synth::noise_rgb(h, w, base + i as u64)).collect()
}

#[test]
fn steady_state_frame_path_allocates_nothing() {
    let (h, w) = (24, 32);
    let built = hermetic_build(h, w, 1, 2);

    // warm-up: shelves fill to the working set (incl. recycled inputs)
    let (warm_out, _) = built.run(frames(h, w, 8, 0)).unwrap();
    assert_eq!(warm_out.len(), 8);
    let warm = built.pool.stats();
    assert!(warm.misses > 0, "cold start must have allocated something");

    // steady state: more frames, zero new allocations
    let (outs, _) = built.run(frames(h, w, 10, 100)).unwrap();
    assert_eq!(outs.len(), 10);
    let steady = built.pool.stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state frame path allocated: {} new misses over 10 frames \
         (hits {} -> {})",
        steady.misses - warm.misses,
        warm.hits,
        steady.hits
    );
    assert!(steady.hits > warm.hits, "the steady-state frames must run off the pool");

    // and the pooled stream stays numerically identical to the original
    let interp = Interpreter::new(
        corner_harris_demo(h, w),
        std::sync::Arc::new(RegistryDispatch::standard()),
    );
    for (i, f) in frames(h, w, 10, 100).into_iter().enumerate() {
        let want = interp.run(&[f]).unwrap().remove(0);
        assert_eq!(outs[i], want, "frame {i} diverges from the original binary");
    }
}

#[test]
fn process_one_reaches_steady_state_too() {
    let (h, w) = (16, 20);
    let built = hermetic_build(h, w, 1, 1);
    for i in 0..4 {
        let _ = built.process_one(synth::noise_rgb(h, w, i)).unwrap();
    }
    let warm = built.pool.stats();
    for i in 0..6 {
        let _ = built.process_one(synth::noise_rgb(h, w, 50 + i)).unwrap();
    }
    assert_eq!(built.pool.stats().misses, warm.misses);
}

#[test]
fn downcycling_stream_reaches_zero_steady_state_misses() {
    // The shelf-migration regression at stream scale: every frame a
    // 3-channel input storage enters the pool, gets downcycled into gray
    // intermediates, and 3-channel storage is needed again.  Under the
    // historical shape-keyed shelves a downcycled (H, W, 3) storage was
    // released under its *new* (H, W) shape — once the gray shelf hit
    // its cap the big storages were dropped while the 3-channel shelf
    // starved, so misses never stopped.  Capacity-class keying returns
    // every storage to its own class and the stream goes fully
    // allocation-free.
    use courier::pipeline::BufferPool;
    let pool = BufferPool::new();
    let (h, w) = (12, 16);
    // more gray intermediates per frame than one shelf's idle cap (32)
    const GRAYS: usize = 36;
    let frame = |pool: &BufferPool| {
        // the dying external input returns its (H, W, 3) storage
        pool.release(Mat::zeros(&[h, w, 3]));
        // a burst of gray intermediates forces downcycling into the
        // 3-channel storages and overflows the small class
        let grays: Vec<Mat> = (0..GRAYS).map(|_| pool.acquire(&[h, w])).collect();
        for g in grays {
            pool.release(g);
        }
        // ...and the next frame needs 3-channel working storage again
        let staged = pool.acquire(&[h, w, 3]);
        pool.release(staged);
    };
    for _ in 0..6 {
        frame(&pool); // warm-up: classes fill to the working set
    }
    let warm = pool.stats().misses;
    for _ in 0..32 {
        frame(&pool);
    }
    assert_eq!(
        pool.stats().misses,
        warm,
        "downcycling stream still allocating in steady state \
         (hits {} misses {})",
        pool.stats().hits,
        pool.stats().misses
    );
}

#[test]
fn banded_pipeline_reaches_zero_steady_state_misses() {
    // banding on: every band of the pooled Gaussian draws its h-pass
    // scratch from the *parent* capacity class (acquire_band_scratch),
    // so sharded stages add no per-band-count shelves and the
    // zero-allocation invariant holds unchanged — the regression this
    // pins is a pool that leaked one shelf per distinct band height
    let (h, w) = (24, 32);
    let tmp = empty_hwdb_dir("pool-steady-bands").unwrap();
    let db = HwDatabase::load(tmp.path()).unwrap();
    let prog = courier::app::parse_program(&format!(
        "program bandedChain\n\
         input frame {h}x{w}x3\n\
         call gray = cv::cvtColor(frame)\n\
         call blur = cv::GaussianBlur(gray)\n\
         call resp = cv::cornerHarris(blur)\n\
         call out = cv::convertScaleAbs(resp)\n\
         output out\n"
    ))
    .unwrap();
    let trace = trace_program(&prog, &[vec![synth::noise_rgb(h, w, 0)]]).unwrap();
    let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
    let cfg = Config {
        artifacts_dir: tmp.path().to_path_buf(),
        cpu_only: true,
        threads: 1,
        tokens: 2,
        bands: 4,
        ..Default::default()
    };
    let built =
        build(&ir, &db, &Runtime::cpu().unwrap(), &Registry::standard(), &cfg).unwrap();
    assert_eq!(built.plan.bands, 4, "the config's band count must reach the plan");

    let (warm_out, _) = built.run(frames(h, w, 8, 0)).unwrap();
    assert_eq!(warm_out.len(), 8);
    let warm = built.pool.stats();
    assert!(warm.misses > 0, "cold start must have allocated something");

    let (outs, _) = built.run(frames(h, w, 12, 200)).unwrap();
    assert_eq!(outs.len(), 12);
    let steady = built.pool.stats();
    assert_eq!(
        steady.misses, warm.misses,
        "banded steady-state frame path allocated: {} new misses over 12 \
         frames (hits {} -> {})",
        steady.misses - warm.misses,
        warm.hits,
        steady.hits
    );
    assert!(steady.hits > warm.hits, "the steady-state frames must run off the pool");

    // and the banded stream stays bit-identical to the original binary
    let interp = Interpreter::new(prog, std::sync::Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames(h, w, 12, 200).into_iter().enumerate() {
        let want = interp.run(&[f]).unwrap().remove(0);
        assert_eq!(outs[i], want, "frame {i} diverges from the original binary");
    }
}

#[test]
fn pool_survives_multi_worker_streams() {
    // more workers/tokens: the invariant loosens to "misses stop growing
    // once shelves cover the peak concurrent working set" — run a large
    // warm-up, then assert a long steady window stays flat
    let (h, w) = (16, 16);
    let built = hermetic_build(h, w, 2, 3);
    let _ = built.run(frames(h, w, 24, 0)).unwrap();
    let warm = built.pool.stats();
    let (outs, _) = built.run(frames(h, w, 24, 500)).unwrap();
    assert_eq!(outs.len(), 24);
    let steady = built.pool.stats();
    // concurrency can in principle deepen the working set mid-window, but
    // it must not grow per-frame: allow at most one extra per-stage
    // working set, not one per frame
    assert!(
        steady.misses - warm.misses <= 8,
        "pool misses grew by {} over 24 steady frames",
        steady.misses - warm.misses
    );
}
