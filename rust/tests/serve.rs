//! Integration: the `courier::serve` multi-tenant serving subsystem.
//!
//! Most tests run hermetically: an empty-but-valid hardware manifest makes
//! every database lookup miss, so pipelines place everything on the CPU
//! and no AOT artifact is required.  One test exercises the hardware path
//! and is gated on `make artifacts`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use courier::app::{corner_harris_demo, Interpreter, RegistryDispatch};
use courier::config::Config;
use courier::image::{synth, Mat};
use courier::serve::{Server, SessionSpec};
use courier::util::testing::{empty_hwdb_dir, TempDir};

/// A v2 manifest matching the corner-Harris case-study ops at `h`x`w`,
/// each module with a real PPA record — no artifact files, so it only
/// supports tests whose builds never reach the fabric (over-budget
/// fallback).  Three modules at 4 800 LUTs each: combined 14 400.
fn harris_ppa_db(tag: &str, h: usize, w: usize) -> TempDir {
    let tmp = TempDir::new(tag).unwrap();
    let module = |name: &str, symbol: &str, in_shape: &str| {
        format!(
            r#"{{
                "name": "{name}",
                "library_symbol": "{symbol}",
                "enabled": true,
                "kind": "image1",
                "variants": [{{
                    "size": [{h}, {w}],
                    "inputs": [{{"shape": [{in_shape}], "dtype": "f32"}}],
                    "outputs": [{{"shape": [{h}, {w}], "dtype": "f32"}}],
                    "artifact": "{name}__{h}x{w}.hlo.txt",
                    "est_flops": 1000.0,
                    "est_bytes": 1000.0,
                    "est_latency_cycles": 256,
                    "ppa": {{"latency_cycles": 256, "area_luts": 4800.0, "power_mw": 120.0}}
                }}]
            }}"#
        )
    };
    let manifest = format!(
        r#"{{"version": 2, "fabric_clock_mhz": 157.0, "modules": [{}, {}, {}]}}"#,
        module("hls_cvt_color", "cv::cvtColor", &format!("{h}, {w}, 3")),
        module("hls_corner_harris", "cv::cornerHarris", &format!("{h}, {w}")),
        module("hls_convert_scale_abs", "cv::convertScaleAbs", &format!("{h}, {w}")),
    );
    std::fs::write(tmp.path().join("manifest.json"), manifest).unwrap();
    tmp
}

#[test]
fn over_budget_cold_build_flips_the_partition_to_software() {
    let tmp = harris_ppa_db("serve-fabric-budget", 24, 32);
    let program = corner_harris_demo(24, 32);

    // the planner itself admits all three modules at the default budget …
    let db = courier::hwdb::HwDatabase::load(tmp.path()).unwrap();
    let inputs = courier::app::synth_frames(&program, 1);
    let trace = courier::trace::trace_program(&program, &inputs).unwrap();
    let ir =
        courier::ir::Ir::from_graph(&courier::trace::CallGraph::from_trace(&trace)).unwrap();
    let registry = courier::swlib::Registry::standard();
    let roomy = Config { artifacts_dir: tmp.path().to_path_buf(), ..Default::default() };
    let plan = courier::pipeline::plan_pipeline(&ir, &db, &registry, &roomy, None).unwrap();
    assert_eq!(plan.placement_counts().0, 3, "default budget admits the case study");
    assert_eq!(plan.fabric_area_luts(), 14_400);

    // … but a budget below the combined 14 400-LUT footprint flips the
    // serve cold build to an all-software plan instead of failing (or
    // panicking): typed fabric error inside, graceful fallback outside
    let mut cfg = serve_config(tmp.path().to_path_buf());
    cfg.serve.fabric_area_luts = 10_000;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(corner_harris_demo(24, 32))).unwrap();
    assert_eq!(
        session.pipeline().plan.placement_counts().0,
        0,
        "fallback plan must be all-software"
    );
    assert_eq!(server.stats().fabric_fallbacks.get(), 1);

    // frames serve correctly on the fallback plan
    let frame = synth::noise_rgb(24, 32, 3);
    let out = session.run_window(vec![frame.clone()]).unwrap().remove(0);
    let original =
        Interpreter::new(corner_harris_demo(24, 32), Arc::new(RegistryDispatch::standard()));
    let want = original.run(&[frame]).unwrap().remove(0);
    assert!(out.quantized_close(&want, 1.0, 1e-3), "fallback output diverges");

    // a second open of the same key is a warm hit on the fallback plan
    // (the fallback is cached under the original key — no rebuild loop)
    let warm = server.open(SessionSpec::new(corner_harris_demo(24, 32))).unwrap();
    assert!(warm.cache_hit());
    assert_eq!(server.stats().fabric_fallbacks.get(), 1, "no second fallback build");

    // the metrics snapshot exports fabric occupancy: nothing is placed
    let snap = server.metrics_snapshot();
    let fabric = snap.req("fabric").unwrap();
    assert_eq!(fabric.req("busy_luts").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(fabric.req("registered_luts").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(
        fabric.req("budget_luts").unwrap().as_f64().unwrap(),
        10_000.0,
        "occupancy is reported against the configured budget"
    );

    server.shutdown();
}

/// A valid artifact dir whose database has no modules (CPU-only serving)
/// — written by the shared `empty_hwdb_dir` helper at TempDir creation.
fn empty_db(tmp: &TempDir) -> PathBuf {
    tmp.path().to_path_buf()
}

fn serve_config(artifacts_dir: PathBuf) -> Config {
    let mut cfg = Config { artifacts_dir, ..Default::default() };
    cfg.serve.workers = 2;
    cfg.serve.queue_depth = 4;
    cfg
}

#[test]
fn second_open_with_identical_key_is_served_from_the_plan_cache() {
    let tmp = empty_hwdb_dir("serve-cache").unwrap();
    let server = Server::new(serve_config(empty_db(&tmp))).unwrap();

    let cold = server.open(SessionSpec::new(corner_harris_demo(64, 80))).unwrap();
    assert!(!cold.cache_hit(), "first open must build");
    assert_eq!(server.cache().misses.get(), 1);
    assert_eq!(server.cache().hits.get(), 0);

    let warm = server.open(SessionSpec::new(corner_harris_demo(64, 80))).unwrap();
    assert!(warm.cache_hit(), "identical key must hit the cache");
    assert_eq!(server.cache().misses.get(), 1, "no rebuild on the second open");
    assert_eq!(server.cache().hits.get(), 1);
    assert!(
        Arc::ptr_eq(cold.pipeline(), warm.pipeline()),
        "both sessions must share one built pipeline"
    );
    assert!(
        warm.open_ns() < cold.open_ns(),
        "warm open ({} ns) must be faster than cold open ({} ns)",
        warm.open_ns(),
        cold.open_ns()
    );

    // a *different* key (other shape) is a fresh build
    let other = server.open(SessionSpec::new(corner_harris_demo(32, 40))).unwrap();
    assert!(!other.cache_hit());
    assert_eq!(server.cache().misses.get(), 2);
    assert_eq!(server.cache().len(), 2);

    // and the served outputs match the original binary
    let frame = synth::noise_rgb(64, 80, 7);
    let got = warm.run_window(vec![frame.clone()]).unwrap().remove(0);
    let original =
        Interpreter::new(corner_harris_demo(64, 80), Arc::new(RegistryDispatch::standard()));
    let want = original.run(&[frame]).unwrap().remove(0);
    assert!(got.quantized_close(&want, 1.0, 1e-3), "served output diverges from binary");

    // the frame ran off the shared buffer pool (one pool per cached plan)
    let pool = warm.pool_stats();
    assert!(pool.acquires() > 0, "served frames must draw from the buffer pool");
    assert_eq!(
        pool, cold.pool_stats(),
        "sessions on one cached plan share one pool"
    );

    server.shutdown();
}

#[test]
fn saturating_one_session_does_not_stall_another() {
    let tmp = empty_hwdb_dir("serve-isolation").unwrap();
    let mut cfg = serve_config(empty_db(&tmp));
    cfg.serve.queue_depth = 2; // tiny ingress bound: saturation is easy
    let server = Server::new(cfg).unwrap();

    // tenant A: heavy frames, hammered without backpressure (try_submit)
    let heavy = server
        .open(SessionSpec::new(corner_harris_demo(160, 200)).named("heavy"))
        .unwrap();
    // tenant B: light frames, polite blocking submits
    let light = server
        .open(SessionSpec::new(corner_harris_demo(32, 40)).named("light"))
        .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let outputs: Vec<Mat> = std::thread::scope(|scope| {
        let saturator = {
            let heavy = heavy.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                let mut tickets = Vec::new();
                let mut seq = 0u64;
                while !stop.load(Ordering::Acquire) {
                    match heavy.try_submit(synth::noise_rgb(160, 200, seq)) {
                        Ok(t) => tickets.push(t),
                        Err(_) => std::thread::yield_now(), // rejected: queue full
                    }
                    seq += 1;
                }
                tickets
            })
        };

        // tenant B streams 8 frames while A is saturated
        let outs: Vec<Mat> = (0..8)
            .map(|i| {
                let t = light.submit(synth::noise_rgb(32, 40, i)).unwrap();
                light.wait(t).unwrap()
            })
            .collect();

        stop.store(true, Ordering::Release);
        // A's accepted frames still complete (no lost work)
        for t in saturator.join().expect("saturator thread") {
            heavy.wait(t).unwrap();
        }
        outs
    });

    assert_eq!(outputs.len(), 8, "light tenant finished under saturation");
    assert_eq!(light.stats.completed.get(), 8);
    assert_eq!(light.stats.rejected.get(), 0, "light tenant was never shed");
    assert!(
        heavy.stats.rejected.get() > 0,
        "bounded queue must have rejected some of the saturating load"
    );
    assert!(heavy.stats.completed.get() > 0, "heavy tenant made progress too");

    // light outputs are correct despite the contention
    let original =
        Interpreter::new(corner_harris_demo(32, 40), Arc::new(RegistryDispatch::standard()));
    for (i, out) in outputs.iter().enumerate() {
        let want = original.run(&[synth::noise_rgb(32, 40, i as u64)]).unwrap().remove(0);
        assert!(out.quantized_close(&want, 1.0, 1e-3), "light frame {i} corrupted");
    }

    server.shutdown();
}

#[test]
fn admission_control_caps_open_sessions() {
    let tmp = empty_hwdb_dir("serve-admission").unwrap();
    let mut cfg = serve_config(empty_db(&tmp));
    cfg.serve.max_sessions = 1;
    let server = Server::new(cfg).unwrap();

    let first = server.open(SessionSpec::new(corner_harris_demo(32, 40))).unwrap();
    let err = match server.open(SessionSpec::new(corner_harris_demo(48, 64))) {
        Err(e) => e,
        Ok(_) => panic!("second session must be refused"),
    };
    assert!(err.to_string().contains("admission"), "{err}");
    assert_eq!(server.stats().sessions_rejected.get(), 1);
    assert_eq!(server.active_sessions(), 1);

    // closing frees the slot
    server.close(&first);
    assert_eq!(server.active_sessions(), 0);
    let again = server.open(SessionSpec::new(corner_harris_demo(48, 64))).unwrap();
    assert!(!again.is_closed());

    // the closed session refuses new frames
    let err = first.submit(synth::noise_rgb(32, 40, 0)).unwrap_err();
    assert!(err.to_string().contains("closed"), "{err}");

    server.shutdown();
}

#[test]
fn close_cancels_queued_frames_but_not_finished_ones() {
    let tmp = empty_hwdb_dir("serve-close").unwrap();
    let mut cfg = serve_config(empty_db(&tmp));
    cfg.serve.workers = 1;
    cfg.serve.queue_depth = 16;
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(corner_harris_demo(120, 160))).unwrap();

    // first frame completes; the rest are likely still queued at close
    let done = session.submit(synth::noise_rgb(120, 160, 0)).unwrap();
    let out = session.wait(done).unwrap();
    assert_eq!(out.shape(), &[120, 160]);

    let pending: Vec<_> = (1..10)
        .map(|i| session.submit(synth::noise_rgb(120, 160, i)).unwrap())
        .collect();
    server.close(&session);
    let mut cancelled = 0;
    for t in pending {
        if session.wait(t).is_err() {
            cancelled += 1;
        }
    }
    assert_eq!(
        cancelled,
        session.stats.cancelled.get(),
        "every cancelled frame surfaced as a wait error"
    );
    assert!(session.stats.completed.get() >= 1);

    server.shutdown();
}

#[test]
fn close_interleaves_with_faulted_frames_without_losing_any() {
    // injected sw panics and a mid-stream close race against one worker:
    // every submitted frame must still be retired exactly once — as a
    // delivered output, a surfaced fault, or a cancellation — and every
    // `wait` must return (a frame whose fault never reached the
    // completion table would hang its caller forever)
    let tmp = empty_hwdb_dir("serve-close-faults").unwrap();
    let mut cfg = serve_config(empty_db(&tmp));
    cfg.serve.workers = 1;
    cfg.serve.queue_depth = 16;
    cfg.fault.enabled = true;
    cfg.fault.kinds = "sw_panic".to_string();
    cfg.fault.period = 2;
    cfg.fault.only = "cornerHarris".to_string();
    let server = Server::new(cfg).unwrap();
    let session = server.open(SessionSpec::new(corner_harris_demo(120, 160))).unwrap();

    // the harris site strikes every 2nd invocation: frame 0 is clean,
    // frame 1 is the poison frame — both retire before the close
    let first = session.submit(synth::noise_rgb(120, 160, 0)).unwrap();
    let poison = session.submit(synth::noise_rgb(120, 160, 1)).unwrap();
    assert!(session.wait(first).is_ok(), "clean frame must deliver");
    let err = session.wait(poison).unwrap_err();
    assert!(err.to_string().contains("injected"), "{err}");

    // now close mid-stream with frames queued behind the worker
    let pending: Vec<_> = (2..12)
        .map(|i| session.submit(synth::noise_rgb(120, 160, i)).unwrap())
        .collect();
    server.close(&session);
    for t in pending {
        let _ = session.wait(t); // Ok, faulted or cancelled — but it returns
    }

    let s = &session.stats;
    assert_eq!(s.submitted.get(), 12);
    assert_eq!(
        s.completed.get() + s.failed.get() + s.cancelled.get(),
        12,
        "every submitted frame retired exactly once (completed {}, failed {}, cancelled {})",
        s.completed.get(),
        s.failed.get(),
        s.cancelled.get()
    );
    assert_eq!(s.in_flight(), 0, "the session owes the client nothing");
    assert!(s.failed.get() >= 1, "the poison frame surfaced as a wait error");
    assert_eq!(server.stats().frame_faults.get(), s.failed.get());

    server.shutdown();
}

#[test]
fn hardware_sessions_share_cached_pjrt_executables() {
    // the real-artifact variant of the cache test (skips without
    // `make artifacts`, like the runtime unit tests)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let server = Server::new(serve_config(dir)).unwrap();
    let a = server.open(SessionSpec::new(corner_harris_demo(48, 64))).unwrap();
    assert!(
        !a.pipeline().plan.hw_modules().is_empty(),
        "case-study pipeline must place hardware modules"
    );
    let b = server.open(SessionSpec::new(corner_harris_demo(48, 64))).unwrap();
    assert!(b.cache_hit());
    assert!(Arc::ptr_eq(a.pipeline(), b.pipeline()));
    assert!(b.open_ns() < a.open_ns(), "warm {} vs cold {}", b.open_ns(), a.open_ns());

    // both tenants stream concurrently and agree with the original binary
    let frames: Vec<Mat> = (0..4).map(|s| synth::noise_rgb(48, 64, 50 + s)).collect();
    let (out_a, out_b) = std::thread::scope(|scope| {
        let fa = frames.clone();
        let fb = frames.clone();
        let ha = scope.spawn(move || a.run_window(fa).unwrap());
        let hb = scope.spawn(move || b.run_window(fb).unwrap());
        (ha.join().unwrap(), hb.join().unwrap())
    });
    let original =
        Interpreter::new(corner_harris_demo(48, 64), Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames.into_iter().enumerate() {
        let want = original.run(&[f]).unwrap().remove(0);
        assert!(out_a[i].quantized_close(&want, 1.0, 1e-3), "tenant a frame {i}");
        assert!(out_b[i].quantized_close(&want, 1.0, 1e-3), "tenant b frame {i}");
    }

    server.shutdown();
}

#[test]
fn dag_program_cold_build_serves_and_matches_the_binary() {
    // serve's cold-build path runs the whole trace -> IR -> partition ->
    // build chain; a DAG-shaped tenant (gray fans out to both Sobels and
    // back in at the corner response) must build a legal plan and serve
    // outputs identical to the original binary
    use courier::app::harris_dag_demo;

    let tmp = empty_hwdb_dir("serve-dag").unwrap();
    let server = Server::new(serve_config(empty_db(&tmp))).unwrap();

    let session = server.open(SessionSpec::new(harris_dag_demo(24, 32))).unwrap();
    assert!(!session.cache_hit());
    let plan = &session.pipeline().plan;
    plan.validate_dag().unwrap();
    assert!(!plan.edges.is_empty(), "DAG plans carry explicit edges");

    let frames: Vec<Mat> = (0..4).map(|s| synth::noise_rgb(24, 32, s)).collect();
    let outs = session.run_window(frames.clone()).unwrap();
    let original =
        Interpreter::new(harris_dag_demo(24, 32), Arc::new(RegistryDispatch::standard()));
    for (i, f) in frames.into_iter().enumerate() {
        let want = original.run(&[f]).unwrap().remove(0);
        assert_eq!(outs[i], want, "frame {i}: served DAG output diverges");
    }

    // a second open of the same DAG tenant hits the plan cache
    let warm = server.open(SessionSpec::new(harris_dag_demo(24, 32))).unwrap();
    assert!(warm.cache_hit());
    assert!(Arc::ptr_eq(session.pipeline(), warm.pipeline()));

    server.shutdown();
}

#[test]
fn multi_output_session_delivers_ordered_bundles() {
    // a Courier-Script tenant with three `output` declarations: every
    // submitted frame resolves to an ordered bundle (`wait_all`), the
    // single-Mat surface streams the primary output, and both are
    // bit-identical to the interpreter
    use courier::app::gaussian_pyramid_demo;

    let tmp = empty_hwdb_dir("serve-multi-out").unwrap();
    let server = Server::new(serve_config(empty_db(&tmp))).unwrap();
    let session = server.open(SessionSpec::new(gaussian_pyramid_demo(24, 32))).unwrap();
    session.pipeline().check_output_matches(&gaussian_pyramid_demo(24, 32)).unwrap();

    let original =
        Interpreter::new(gaussian_pyramid_demo(24, 32), Arc::new(RegistryDispatch::standard()));
    let frames: Vec<Mat> = (0..4).map(|s| synth::noise_rgb(24, 32, s)).collect();
    let bundles = session.run_window_all(frames.clone()).unwrap();
    for (i, f) in frames.iter().enumerate() {
        let want = original.run(std::slice::from_ref(f)).unwrap();
        assert_eq!(want.len(), 3);
        assert_eq!(bundles[i], want, "frame {i}: served bundle diverges");
    }

    // the legacy single-output surface is the bundle's primary entry
    let t = session.submit(frames[0].clone()).unwrap();
    let primary = session.wait(t).unwrap();
    assert_eq!(primary, bundles[0][0]);

    server.shutdown();
}
