//! Stress: the token-pipeline runtime under adversarial per-stage jitter.
//!
//! Each middle stage sleeps a pseudo-random (seeded, per-token,
//! per-stage) duration, maximizing reordering pressure on the serial
//! head/tail and contention on the token pool.  Asserted invariants:
//!
//! 1. **ordering** — outputs come back in input order and every serial
//!    stage processes tokens in strictly increasing sequence without
//!    overlapping itself;
//! 2. **no deadlock** — the run completes (a hang fails the test by
//!    never returning);
//! 3. **bounded in-flight tokens** — at no instant do more than `tokens`
//!    frames have overlapping lifetimes (this is the invariant the
//!    historical injection race violated: the pool-slot check and the
//!    increment were not atomic, so racing workers could overshoot the
//!    token pool by up to `threads - 1`).
//!
//! All randomness is seeded (`util::rng::Rng`); no wall-clock assertions.

use courier::image::Mat;
use courier::pipeline::{FilterMode, FnFilter, PipelineStats, StageFilter, TokenPipeline};
use courier::util::rng::Rng;

/// Deterministic per-(token, stage) jitter in [0, max_us).
fn jitter_us(seed: u64, token: u64, stage: u64, max_us: u64) -> u64 {
    Rng::new(seed ^ (token << 8) ^ stage).next_u64() % max_us
}

fn jitter_filter(mode: FilterMode, stage: u64, seed: u64, max_us: u64, delta: f32) -> Box<dyn StageFilter> {
    Box::new(FnFilter {
        mode,
        label: format!("jitter{stage}"),
        f: move |mut m: Mat| {
            let token = m.at2(0, 0).floor() as u64;
            let us = jitter_us(seed, token, stage, max_us);
            if us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(us));
            }
            for v in m.as_mut_slice() {
                *v += delta;
            }
            Ok(m)
        },
    })
}

/// Token lifetimes from spans: [first span start, last span end] per
/// token, swept for the maximum simultaneous overlap.
fn peak_tokens_in_flight(stats: &PipelineStats) -> usize {
    use std::collections::HashMap;
    let mut lifetime: HashMap<u64, (u64, u64)> = HashMap::new();
    for s in &stats.spans {
        let e = lifetime.entry(s.token).or_insert((s.start_ns, s.end_ns));
        e.0 = e.0.min(s.start_ns);
        e.1 = e.1.max(s.end_ns);
    }
    let mut edges: Vec<(u64, i64)> = Vec::with_capacity(lifetime.len() * 2);
    for (_, (a, b)) in lifetime {
        edges.push((a, 1));
        edges.push((b, -1));
    }
    edges.sort_unstable();
    let (mut cur, mut peak) = (0i64, 0i64);
    for (_, d) in edges {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

fn run_stress(frames: usize, threads: usize, tokens: usize, seed: u64, max_us: u64) {
    let pipe = TokenPipeline::new(
        vec![
            jitter_filter(FilterMode::SerialInOrder, 0, seed, max_us / 4, 0.125),
            jitter_filter(FilterMode::Parallel, 1, seed, max_us, 0.125),
            jitter_filter(FilterMode::Parallel, 2, seed.rotate_left(17), max_us, 0.125),
            jitter_filter(FilterMode::SerialInOrder, 3, seed, max_us / 4, 0.125),
        ],
        threads,
        tokens,
    )
    .unwrap();
    let inputs: Vec<Mat> = (0..frames).map(|i| Mat::full(&[1, 1], i as f32)).collect();

    // 2) completing at all is the no-deadlock assertion
    let (out, stats) = pipe.run(inputs).unwrap();

    // 1a) outputs in input order with the right values
    assert_eq!(out.len(), frames);
    for (i, m) in out.iter().enumerate() {
        assert_eq!(m.at2(0, 0), i as f32 + 0.5, "frame {i} out of order or corrupted");
    }
    assert_eq!(stats.frames, frames as u64);
    assert_eq!(stats.spans.len(), frames * 4, "every token must traverse every stage once");

    // 1b) serial stages: strictly increasing token order, no self-overlap
    for stage in [0usize, 3] {
        let mut spans: Vec<_> = stats.spans.iter().filter(|s| s.stage == stage).collect();
        spans.sort_by_key(|s| s.start_ns);
        assert_eq!(spans.len(), frames);
        for w in spans.windows(2) {
            assert!(
                w[0].token < w[1].token,
                "serial stage {stage} ran token {} before {}",
                w[1].token,
                w[0].token
            );
            assert!(
                w[0].end_ns <= w[1].start_ns,
                "serial stage {stage} overlapped itself: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    // 3) bounded in-flight tokens — primary: the pool's own high-water
    // mark (covers frames still queued ahead of their first stage, where
    // the historical overshoot race parked them); secondary: the span
    // sweep, which must agree as a lower bound
    assert!(
        stats.peak_in_flight <= tokens,
        "token pool violated: {} frames in flight with a pool of {tokens}",
        stats.peak_in_flight
    );
    let span_peak = peak_tokens_in_flight(&stats);
    assert!(
        span_peak <= stats.peak_in_flight,
        "span-derived concurrency {span_peak} exceeds the pool's own accounting {}",
        stats.peak_in_flight
    );
}

#[test]
fn stress_2k_frames_with_adversarial_jitter() {
    run_stress(2_000, 4, 3, 0xC0FFEE, 24);
}

#[test]
fn stress_tight_pool_and_single_thread_degenerate() {
    // pool of 1 serializes everything; 1 thread must still complete
    run_stress(500, 4, 1, 7, 16);
    run_stress(500, 1, 4, 11, 8);
}

/// `peak_in_flight` is exact: on a deterministic schedule that provably
/// saturates the token pool, the high-water mark must EQUAL the
/// configured overlap — not merely stay under the pool bound.  (The
/// historical metric counted racing empty-feed reservations and could
/// read up to `threads - 1` high; an exact metric makes the equality
/// assertion possible at all.)
///
/// Schedule: `TOKENS` frames, `TOKENS + 1` workers, a middle `parallel`
/// stage that blocks every token on a condvar until all `TOKENS` tokens
/// have entered it.  No emission can happen before every frame is
/// injected, so the claimed-frame counter reaches exactly `TOKENS`; the
/// spare worker keeps the serial head and the injection loop running
/// while the others hold the gate.  No timing assumptions anywhere.
#[test]
fn peak_in_flight_equals_configured_overlap_on_a_deterministic_schedule() {
    use std::sync::{Arc, Condvar, Mutex};

    const TOKENS: usize = 3;
    struct Gate {
        entered: Mutex<usize>,
        cv: Condvar,
    }
    let gate = Arc::new(Gate { entered: Mutex::new(0), cv: Condvar::new() });
    let g = gate.clone();
    let blocking = Box::new(FnFilter {
        mode: FilterMode::Parallel,
        label: "gate".into(),
        f: move |m: Mat| {
            let mut n = g.entered.lock().unwrap();
            *n += 1;
            if *n >= TOKENS {
                g.cv.notify_all();
            }
            while *n < TOKENS {
                n = g.cv.wait(n).unwrap();
            }
            Ok(m)
        },
    });
    let pass = |label: &str| -> Box<dyn StageFilter> {
        Box::new(FnFilter {
            mode: FilterMode::SerialInOrder,
            label: label.to_string(),
            f: |m: Mat| Ok(m),
        })
    };
    let pipe = TokenPipeline::new(vec![pass("head"), blocking, pass("tail")], TOKENS + 1, TOKENS)
        .unwrap();
    let inputs: Vec<Mat> = (0..TOKENS).map(|i| Mat::full(&[1, 1], i as f32)).collect();
    let (out, stats) = pipe.run(inputs).unwrap();
    assert_eq!(out.len(), TOKENS);
    assert_eq!(
        stats.peak_in_flight, TOKENS,
        "exact metric must equal the configured overlap on a pool-saturating schedule"
    );
}

/// The full 10k-frame sweep (release-mode slow job: `cargo test -q -- --ignored`).
#[test]
#[ignore = "slow: 10k frames; run in the CI slow-test job"]
fn stress_10k_frames_with_adversarial_jitter() {
    run_stress(10_000, 4, 3, 0xDEADBEEF, 32);
    run_stress(10_000, 8, 5, 0xFEEDFACE, 16);
}
