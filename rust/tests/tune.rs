//! Integration: the `courier::tune` autotuner.
//!
//! * **Sim-vs-reality regression** — for the three bundled example specs,
//!   the simulator's predicted stage ordering must agree with the
//!   measured `PipelineStats` ordering from a real run (compared only
//!   where the prediction separates stages by >= 4x, so the assertion is
//!   deterministic under scheduler noise).
//! * **Never-regress** — the tuner must not return a plan the simulator
//!   scores worse than the seed plan, and its report must show at least
//!   one rejected candidate.
//! * **Serve re-tune** — promoting the tuned plan upgrades the session
//!   key for subsequent opens without invalidating in-flight sessions.
//!
//! Everything runs hermetically against an empty hardware manifest
//! (every lookup misses -> CPU-only pipelines, no AOT artifacts needed).

use std::path::PathBuf;
use std::sync::Arc;

use courier::app::{corner_harris_demo, edge_demo, synth_frames, Program};
use courier::config::Config;
use courier::hwdb::HwDatabase;
use courier::ir::Ir;
use courier::pipeline::simulate;
use courier::runtime::Runtime;
use courier::serve::{Server, SessionSpec};
use courier::swlib::Registry;
use courier::trace::{trace_program, CallGraph};
use courier::tune::Tuner;
use courier::util::testing::{empty_hwdb_dir, TempDir};

fn empty_db(tmp: &TempDir) -> PathBuf {
    tmp.path().to_path_buf()
}

fn tune_config(artifacts_dir: PathBuf) -> Config {
    let mut cfg = Config { artifacts_dir, ..Default::default() };
    cfg.tune.budget = 24;
    cfg.tune.sim_frames = 16;
    cfg.tune.measure_frames = 4;
    cfg
}

/// The three bundled example specs the regression sweeps.
fn bundled_specs() -> Vec<Program> {
    vec![corner_harris_demo(48, 64), edge_demo(48, 64), corner_harris_demo(96, 128)]
}

#[test]
fn simulator_stage_ordering_matches_reality_on_bundled_specs() {
    let tmp = empty_hwdb_dir("tune-simreal").unwrap();
    let cfg = tune_config(empty_db(&tmp));
    let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();

    let mut compared_total = 0;
    for prog in bundled_specs() {
        let inputs = synth_frames(&prog, cfg.trace_frames);
        let trace = trace_program(&prog, &inputs).unwrap();
        let ir = Ir::from_graph(&CallGraph::from_trace(&trace)).unwrap();
        let built = courier::pipeline::build(&ir, &db, &rt, &registry, &cfg).unwrap();

        let frames = 24u64;
        let stream = synth_frames(&prog, frames as usize)
            .into_iter()
            .map(|mut v| v.remove(0))
            .collect();
        let (_, stats) = built.run(stream).unwrap();
        let sim = simulate(&built.plan, frames, built.plan.threads, built.plan.tokens);

        let n = built.plan.stages.len();
        assert!(n >= 2, "{}: bundled specs partition into >= 2 stages", prog.name);
        // predicted-vs-measured ordering: wherever the simulator separates
        // two stages by >= 4x busy time AND the heavy side carries real
        // work (>= 8 ms predicted over the stream), reality must order
        // them the same way.  Both guards keep the assertion
        // deterministic on a loaded runner: a few-ms scheduler
        // preemption can inflate a microseconds-light stage's measured
        // busy time, but not past a neighbour predicted 4x heavier that
        // itself runs for tens of milliseconds (corner-Harris dominates
        // by far more than 4x).
        const HEAVY_FLOOR_NS: u64 = 8_000_000;
        for i in 0..n {
            for j in 0..n {
                if sim.stage_busy_ns[i] >= 4 * sim.stage_busy_ns[j].max(1)
                    && sim.stage_busy_ns[i] >= HEAVY_FLOOR_NS
                {
                    assert!(
                        stats.stage_busy_ns(i) > stats.stage_busy_ns(j),
                        "{}: sim orders stage {i} ({} ns) over stage {j} ({} ns) but \
                         measurement disagrees ({} vs {} ns)",
                        prog.name,
                        sim.stage_busy_ns[i],
                        sim.stage_busy_ns[j],
                        stats.stage_busy_ns(i),
                        stats.stage_busy_ns(j)
                    );
                    compared_total += 1;
                }
            }
        }
    }
    // a well-partitioned plan balances stages, so some specs may have no
    // 4x-separated pair — but across all three the sweep must bite
    assert!(compared_total > 0, "no stage pair separated by 4x anywhere; regression lost its teeth");
}

#[test]
fn tuner_never_returns_a_plan_simulated_worse_than_seed() {
    let tmp = empty_hwdb_dir("tune-noregress").unwrap();
    let cfg = tune_config(empty_db(&tmp));
    let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();

    for prog in bundled_specs() {
        let tuner = Tuner::new(&db, &rt, &registry, &cfg);
        let out = tuner.tune(&prog).unwrap();
        assert!(
            out.report.winner_ms <= out.report.seed_ms,
            "{}: tuned plan simulated at {} ms, seed at {} ms",
            prog.name,
            out.report.winner_ms,
            out.report.seed_ms
        );
        assert!(
            out.report.rows.iter().any(|r| r.verdict.starts_with("rejected")),
            "{}: TUNE report must show at least one rejected candidate",
            prog.name
        );
        assert!(
            out.report.rows.iter().any(|r| r.verdict.contains("winner")),
            "{}: TUNE report must mark a winner",
            prog.name
        );
        assert!(out.report.calibration_entries > 0, "{}: calibration recorded nothing", prog.name);
    }
}

#[test]
fn cost_db_persists_and_sharpens_across_runs() {
    let tmp = empty_hwdb_dir("tune-persist").unwrap();
    let mut cfg = tune_config(empty_db(&tmp));
    let db_path = tmp.path().join("cost_db.json");
    cfg.tune.cost_db = Some(db_path.clone());
    let db = HwDatabase::load(&cfg.artifacts_dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let registry = Registry::standard();
    let prog = corner_harris_demo(32, 40);

    let tuner = Tuner::new(&db, &rt, &registry, &cfg);
    let first = tuner.tune(&prog).unwrap();
    first.cost_db.save(&db_path).unwrap();
    assert!(db_path.exists());

    let loaded = courier::tune::CalibratedCostDb::load_or_default(&db_path).unwrap();
    assert_eq!(loaded, first.cost_db);
    let second = tuner.tune_with_db(&prog, loaded).unwrap();
    let key = "cv::cornerHarris@32x40#sw";
    assert!(
        second.cost_db.get(key).unwrap().samples > first.cost_db.get(key).unwrap().samples,
        "persisted calibrations must keep accumulating"
    );
}

#[test]
fn serve_reuses_the_promoted_plan_for_the_same_key() {
    let tmp = empty_hwdb_dir("tune-serve").unwrap();
    let mut cfg = tune_config(empty_db(&tmp));
    cfg.serve.workers = 2;
    // tokens = 1 disables cross-frame overlap entirely, so the seed plan
    // is provably suboptimal under the simulator and the tuner should
    // find an improvement (any tokens >= 2 overlaps strictly better)
    cfg.tokens = 1;
    let server = Server::new(cfg).unwrap();
    let spec = || SessionSpec::new(corner_harris_demo(32, 40));

    // an in-flight session on the untuned plan
    let before = server.open(spec()).unwrap();
    let untuned = before.pipeline().clone();

    // re-tune the key
    let outcome = server.retune(&spec()).unwrap();
    assert!(outcome.report.winner_ms <= outcome.report.seed_ms);

    // the in-flight session is untouched and still serves correctly
    assert!(Arc::ptr_eq(before.pipeline(), &untuned), "in-flight session must keep its plan");
    let frame = courier::image::synth::noise_rgb(32, 40, 5);
    let out = before.run_window(vec![frame.clone()]).unwrap().remove(0);
    assert_eq!(out.shape(), &[32, 40]);

    // the next open for the same key: a promoted winner is reused as a
    // warm hit; an unimproved tune promotes nothing and the original
    // cached plan keeps serving (never a downgrade)
    let after = server.open(spec()).unwrap();
    assert!(after.cache_hit(), "post-retune open must be served from the cache");
    if outcome.improved {
        assert_eq!(server.cache().promotions.get(), 1);
        assert!(
            Arc::ptr_eq(after.pipeline(), &outcome.winner),
            "post-promotion open must get the tuned plan"
        );
    } else {
        assert_eq!(server.cache().promotions.get(), 0);
        assert!(
            Arc::ptr_eq(after.pipeline(), &untuned),
            "unimproved tune must leave the cached plan alone"
        );
    }
    // either way the served plan computes the same function
    let want = untuned.process_one(frame.clone()).unwrap();
    let got = after.pipeline().process_one(frame).unwrap();
    assert!(got.quantized_close(&want, 1.0, 1e-3), "served plan diverges after retune");

    server.shutdown();
}
